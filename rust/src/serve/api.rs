//! The typed service API: every operation the serving layer supports —
//! data plane (`Infer`), admin plane (`Load`/`LoadSeeded`/`Swap`/
//! `Unload`) and observability plane (`ListModels`/`ModelInfo`/
//! `Stats`) — expressed as one [`Request`]/[`Response`] pair, with a
//! single [`Service::dispatch`] both the in-process callers and the
//! TCP endpoint (`serve::net`) route through. A remote call is
//! therefore the same call: same registry mutation, same
//! [`ModelStamp`] on the response, same refcompute cross-checkability.
//!
//! Errors never escape as `Err`: `dispatch` folds every failure into
//! [`Response::Error`], so the wire protocol needs exactly one
//! response envelope and local callers can match on it the same way a
//! remote client does.
//!
//! [`RegistryManifest`] is the persistence satellite: with
//! `serve --registry-file PATH`, every API-plane registry mutation
//! rewrites a small JSON manifest (name, zoo id, weight seed,
//! version, and the model's full per-model [`ArchConfig`]), and a
//! restarted server reloads the exact model set — versions, weights
//! *and mappings* bit-identical, because weights are a pure function
//! of (network, seed) and the program is a pure function of
//! (network, weights, arch).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::explore::MappingChoice;
use crate::coordinator::{ArchConfig, Placement, PoolingScheme, Program, TileMask};
use crate::model::{zoo, Network};
use crate::sim::fault::{corruption_verdict, FaultPlan};
use crate::sim::flight::{self, LinkHeatmap, RecorderConfig};
use crate::sim::Simulator;
use crate::testutil::Rng;

use super::metrics::ModelMetricsSnapshot;
use super::registry::{ModelRegistry, ModelStamp, ModelVersion};
use super::server::Server;

/// Per-model mapping overrides carried by `Load`/`LoadSeeded`: every
/// field is optional and falls back to the service-wide default arch.
/// This is how an explorer winner (`domino map explore`) travels over
/// the wire into a registry load.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MappingSpec {
    pub pooling: Option<PoolingScheme>,
    pub placement: Option<Placement>,
    pub mesh_cols: Option<u64>,
    pub chip_aligned: Option<bool>,
    pub sync_chips: Option<u64>,
}

impl MappingSpec {
    /// A fully-specified spec carrying an explorer choice. A
    /// `MappingChoice` does not sweep `sync_chips`, so that field is
    /// left `None` here — when the scored candidate's base arch had a
    /// duplication budget, copy it in (`spec.sync_chips =
    /// cand.arch.sync_chips.map(..)`) before shipping the spec to a
    /// server whose defaults may differ, or the loaded mapping will
    /// not match the ranked table.
    pub fn of_choice(c: &MappingChoice) -> Self {
        Self {
            pooling: Some(c.pooling),
            placement: Some(c.placement),
            mesh_cols: Some(c.mesh_cols as u64),
            chip_aligned: Some(c.chip_aligned),
            sync_chips: None,
        }
    }

    pub fn is_empty(&self) -> bool {
        *self == Self::default()
    }

    /// Apply the overrides onto the service default, validating the
    /// resulting geometry.
    pub fn apply(&self, mut arch: ArchConfig) -> Result<ArchConfig> {
        if let Some(p) = self.pooling {
            arch.pooling = p;
        }
        if let Some(p) = self.placement {
            arch.placement = p;
        }
        if let Some(m) = self.mesh_cols {
            // checked conversion: a value past usize must be the typed
            // range error below, not a silent truncation on 32-bit
            arch.mesh_cols = usize::try_from(m).unwrap_or(usize::MAX);
        }
        if let Some(b) = self.chip_aligned {
            arch.chip_aligned_chains = b;
        }
        if let Some(s) = self.sync_chips {
            // bound the budget so `chips * tiles_per_chip` (the
            // water-fill arithmetic) cannot overflow on a hostile
            // request — a typed error, not a panic
            let chips = usize::try_from(s).ok().filter(|c| {
                c.checked_mul(arch.tiles_per_chip).is_some()
            });
            anyhow::ensure!(
                chips.is_some(),
                "mapping: sync_chips {s} is out of range for {} tiles/chip",
                arch.tiles_per_chip
            );
            arch.sync_chips = chips;
        }
        anyhow::ensure!(
            arch.mesh_cols > 0 && arch.mesh_cols <= arch.tiles_per_chip,
            "mapping: mesh_cols {} must be in 1..={} (tiles per chip)",
            arch.mesh_cols,
            arch.tiles_per_chip
        );
        Ok(arch)
    }
}

/// A typed request on the service API. `Infer` is the data plane;
/// `Load`/`LoadSeeded`/`Swap`/`Unload` the admin plane (zoo model
/// names, case-insensitive); `ListModels`/`ModelInfo`/`Stats` the
/// observability plane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    /// Run one image. `model: None` routes to the sole loaded model
    /// (exactly like `Server::submit`); `Some(name)` routes by name.
    Infer { model: Option<String>, image: Vec<i8> },
    /// Compile and publish a zoo model under its canonical name, with
    /// the compiler's deterministic default weight seed and an
    /// optional per-model mapping.
    Load {
        model: String,
        mapping: Option<MappingSpec>,
    },
    /// [`Request::Load`] with an explicit weight seed.
    LoadSeeded {
        model: String,
        seed: u64,
        mapping: Option<MappingSpec>,
    },
    /// Hot-swap a loaded model to a freshly compiled version;
    /// `seed: Some(_)` makes the swap observable in the outputs.
    Swap { model: String, seed: Option<u64> },
    /// Remove a model; in-flight requests drain on their version.
    Unload { model: String },
    /// Describe every loaded model.
    ListModels,
    /// Describe one loaded model.
    ModelInfo { model: String },
    /// Per-model serving metrics (p50/p95/p99, counts, queue depth).
    Stats,
    /// Record one seeded image on `model` under a flight recorder and
    /// return the first `window` events plus a link-utilization
    /// heatmap of the busiest stage — the observability plane's answer
    /// to "*why* did p99 move" (see [`crate::sim::flight`]).
    Trace {
        model: String,
        image_seed: u64,
        window: u64,
    },
    /// Arm a deterministic [`FaultPlan`] on `model` (the fault plane):
    /// every subsequent `Infer` for the model runs through a
    /// fault-injecting engine, so the service behaves exactly like one
    /// whose CIM tiles / NoC links silently corrupt values. `plan` is
    /// the `;`-separated site-spec string ([`FaultPlan::parse`]); the
    /// empty string disarms. Arming runs one seeded diagnostic
    /// inference and reports which sites fired plus the corruption
    /// verdict against the refcompute oracle.
    FaultInject { model: String, plan: String },
    /// Sentinel health check: run one seeded canary image through the
    /// data plane (armed fault plans included) and cross-check it
    /// against [`ModelVersion::refcompute`]. A mismatch marks the
    /// model degraded in `Stats`; with `heal`, the service re-maps the
    /// model around the armed plan's fault sites
    /// (`ModelRegistry::remap_masked`) and re-checks — the fault stays
    /// armed, the re-mapped program just never touches the bad tiles.
    Canary { model: String, seed: u64, heal: bool },
}

/// The response envelope for every [`Request`]. Failures are
/// [`Response::Error`] — never a transport-level error — so local and
/// remote callers handle them identically.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Response {
    Infer(InferReply),
    Loaded(ModelStamp),
    Swapped(ModelStamp),
    Unloaded(ModelStamp),
    Models(Vec<ModelDesc>),
    Info(ModelDesc),
    Stats(StatsReply),
    Trace(TraceReply),
    Fault(FaultReply),
    Canary(CanaryReply),
    Error { message: String },
}

/// The `FaultInject` payload: what was armed and what the diagnostic
/// run saw. `fires`/`lanes` come from the typed
/// [`crate::sim::FaultReport`]; `corrupted`/`mismatched`/`outputs` are
/// the verdict of the diagnostic scores against the refcompute oracle
/// — a plan can be armed yet silent (sites the mapping never exercises
/// or a transient window that never opens).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultReply {
    pub model: ModelStamp,
    /// `false` means the request disarmed the model's plan.
    pub armed: bool,
    /// Fault sites in the armed plan.
    pub sites: u64,
    /// Site activations during the diagnostic run.
    pub fires: u64,
    /// Output lanes corrupted during the diagnostic run.
    pub lanes: u64,
    pub corrupted: bool,
    /// Diagnostic scores diverging from the oracle.
    pub mismatched: u64,
    /// Scores compared.
    pub outputs: u64,
    /// Rendered per-site fault report (human-readable).
    pub report: String,
}

/// The `Canary` payload. `model` stamps the version the sentinel ran
/// against; `version` is the version published when the dispatch
/// returned (bumped past the stamp when a heal re-mapped). `ok` is the
/// initial check; `healed` whether the post-re-map re-check came back
/// clean.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CanaryReply {
    pub model: ModelStamp,
    pub ok: bool,
    /// Canary scores diverging from the oracle on the initial check.
    pub mismatched: u64,
    /// Scores compared.
    pub outputs: u64,
    /// A heal re-mapped the model around the armed plan's sites.
    pub remapped: bool,
    /// The post-heal re-check was refcompute-exact.
    pub healed: bool,
    /// Currently published version of the model.
    pub version: u64,
}

/// A served inference: the logits plus the exact model version that
/// produced them ([`ModelStamp`], for refcompute cross-checks) and the
/// server-side timing split.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InferReply {
    pub logits: Vec<i8>,
    /// `None` only on the single-model PJRT backend.
    pub model: Option<ModelStamp>,
    /// Time the request spent queued (microseconds).
    pub queue_us: u64,
    /// Executor time attributed to the request (microseconds).
    pub exec_us: u64,
}

/// The mapping a model runs at, plus its analytic placement stats —
/// the observability plane's view of the mapping plane. Integer-only
/// so it is wire-exact (`worst_link_permille` is load x1000;
/// `pj_per_image` is picojoules).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MappingDesc {
    pub pooling: String,
    pub placement: String,
    pub mesh_cols: u64,
    pub chip_aligned: bool,
    pub sync_chips: Option<u64>,
    pub tiles: u64,
    pub chips: u64,
    /// Worst offered mesh-link load across both router networks, in
    /// permille of a 40 Gb/s link (1000 = saturated).
    pub worst_link_permille: u64,
    /// Analytic pipelined throughput (perfmodel), rounded.
    pub images_per_s: u64,
    /// Analytic energy per image (generic SRAM CIM model), picojoules.
    pub pj_per_image: u64,
}

impl MappingDesc {
    /// Describe a compiled program's mapping. Weight-independent, so
    /// analysis-only (skeleton) programs work too. The numbers come
    /// from `coordinator::explore::analyze` — the same function the
    /// explorer scores candidates with, so `ModelInfo` can never
    /// disagree with the ranked table.
    pub fn of_program(p: &Program) -> Result<Self> {
        let s = crate::coordinator::explore::analyze(p)?;
        Ok(Self {
            pooling: p.arch.pooling.name().to_string(),
            placement: p.arch.placement.name().to_string(),
            mesh_cols: p.arch.mesh_cols as u64,
            chip_aligned: p.arch.chip_aligned_chains,
            sync_chips: p.arch.sync_chips.map(|c| c as u64),
            tiles: s.tiles as u64,
            chips: s.chips as u64,
            worst_link_permille: (s.worst_link_utilization * 1000.0).round() as u64,
            images_per_s: s.images_per_s.round() as u64,
            pj_per_image: (s.energy_per_image_j * 1e12).round() as u64,
        })
    }
}

/// Static description of a model. `id`/`version` are 0 when the model
/// is described from the zoo rather than a live registry entry
/// (`domino models --json`); `mapping` is present for live registry
/// entries and for zoo descriptions computed at an explicit arch
/// ([`ModelDesc::of_network_mapped`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelDesc {
    pub name: String,
    pub id: u64,
    pub version: u64,
    pub input_len: u64,
    pub classes: u64,
    pub layers: u64,
    pub params: u64,
    pub macs: u64,
    pub mapping: Option<MappingDesc>,
}

impl ModelDesc {
    /// Describe a network that is not (necessarily) loaded.
    pub fn of_network(net: &Network) -> Result<Self> {
        Ok(Self {
            name: net.name.clone(),
            id: 0,
            version: 0,
            input_len: net.input_len() as u64,
            classes: net.output_shape()?.c as u64,
            layers: net.layers.len() as u64,
            params: net.total_params()?,
            macs: net.total_macs()?,
            mapping: None,
        })
    }

    /// [`Self::of_network`] plus the mapping stats the network would
    /// have at `arch` (analysis-only compile; `domino models info`).
    pub fn of_network_mapped(net: &Network, arch: ArchConfig) -> Result<Self> {
        let mut d = Self::of_network(net)?;
        let program = crate::coordinator::Compiler::new(arch).compile_analysis(net)?;
        d.mapping = Some(MappingDesc::of_program(&program)?);
        Ok(d)
    }

    /// Describe a live registry entry, including its actual mapping
    /// (cached on the version — observability polling does not rerun
    /// the analysis).
    pub fn of_version(mv: &ModelVersion) -> Result<Self> {
        let mut d = Self::of_network(&mv.program().net)?;
        d.name = mv.name().to_string();
        d.id = mv.id();
        d.version = mv.version();
        d.mapping = Some(mv.mapping_desc()?.clone());
        Ok(d)
    }
}

/// The `Stats` payload: the former aggregate counters plus the
/// per-model split ([`ModelMetricsSnapshot`]: served/failed/rejected
/// counts, live queue-depth gauge, p50/p95/p99 latency) and the
/// endpoint-level shedding counters — connections refused at the TCP
/// accept loop (over `max_conns`) and traces rejected by the
/// concurrent-trace budget. Both used to be invisible: an operator
/// watching `Stats` could not tell connection-level shedding from a
/// quiet endpoint.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StatsReply {
    pub served: u64,
    pub rejected: u64,
    pub failed: u64,
    /// Connections refused over capacity at the TCP endpoint.
    pub conns_refused: u64,
    /// `Request::Trace` dispatches rejected by the trace budget.
    pub trace_rejected: u64,
    pub models: Vec<ModelMetricsSnapshot>,
}

/// The `Trace` payload: a flight recording of one seeded image on the
/// stamped model version. `events` carries the first `window` events
/// of the stream (`events_total` is the full recorded length, so a
/// client knows it saw a prefix); `heatmap` is the rendered
/// link-utilization grid of the busiest stage. `scores` lets a client
/// cross-check the traced run against `Infer`/refcompute — a
/// recording of a run that computed the wrong thing is worthless.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceReply {
    pub model: ModelStamp,
    pub image_seed: u64,
    /// Events in the full recording (before the `window` cut).
    pub events_total: u64,
    /// Events the recorder's ring evicted during the run.
    pub dropped: u64,
    pub events: Vec<flight::Event>,
    pub scores: Vec<i8>,
    pub heatmap: String,
}

/// One persisted registry entry: enough to recompile the exact same
/// model version after a restart — including its full per-model
/// [`ArchConfig`], so a model loaded at a non-default mapping comes
/// back at *that* mapping (restoring with the service-wide default
/// used to silently re-map it, changing all its energy/latency
/// numbers across a restart).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Canonical zoo name to recompile from.
    pub zoo: String,
    /// Weight seed (`None` = the compiler's deterministic default).
    pub seed: Option<u64>,
    /// Version to republish at (preserved across restarts).
    pub version: u64,
    /// The exact arch the model was compiled with. `None` only for
    /// manifests written before mappings were persisted; those restore
    /// at the service-wide default.
    pub arch: Option<ArchConfig>,
}

/// The on-disk registry manifest behind `serve --registry-file PATH`:
/// a JSON document (written with the `serve::wire` encoder) rewritten
/// atomically on every API-plane registry mutation and replayed into a
/// fresh [`ModelRegistry`] on restart.
pub struct RegistryManifest {
    path: PathBuf,
    entries: Mutex<BTreeMap<String, ManifestEntry>>,
}

impl RegistryManifest {
    /// Open (and parse) the manifest at `path`; a missing file is an
    /// empty manifest, a malformed one is an error.
    pub fn open(path: &Path) -> Result<Self> {
        let entries = if path.exists() {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("read registry manifest {}", path.display()))?;
            Self::parse(&text)
                .with_context(|| format!("parse registry manifest {}", path.display()))?
        } else {
            BTreeMap::new()
        };
        Ok(Self {
            path: path.to_path_buf(),
            entries: Mutex::new(entries),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.lock().unwrap().is_empty()
    }

    fn parse(text: &str) -> Result<BTreeMap<String, ManifestEntry>> {
        use super::wire::{self, Json};
        let doc = wire::decode(text)?;
        let models = doc
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest has no \"models\" array"))?;
        let mut entries = BTreeMap::new();
        for m in models {
            let name = wire::str_field(m, "name")?;
            let entry = ManifestEntry {
                zoo: wire::str_field(m, "zoo")?,
                seed: wire::opt_u64_field(m, "seed")?,
                version: wire::u64_field(m, "version")?,
                arch: match m.get("arch") {
                    None | Some(Json::Null) => None,
                    Some(a) => Some(wire::arch_from_json(a)?),
                },
            };
            entries.insert(name, entry);
        }
        Ok(entries)
    }

    fn entries_to_json(entries: &BTreeMap<String, ManifestEntry>) -> super::wire::Json {
        use super::wire::Json;
        let models: Vec<Json> = entries
            .iter()
            .map(|(name, e)| {
                Json::Obj(vec![
                    ("name".to_string(), Json::Str(name.clone())),
                    ("zoo".to_string(), Json::Str(e.zoo.clone())),
                    (
                        "seed".to_string(),
                        match e.seed {
                            Some(s) => Json::Int(s as i128),
                            None => Json::Null,
                        },
                    ),
                    ("version".to_string(), Json::Int(e.version as i128)),
                    (
                        "arch".to_string(),
                        match &e.arch {
                            Some(a) => super::wire::arch_to_json(a),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect();
        Json::Obj(vec![("models".to_string(), Json::Arr(models))])
    }

    /// Record (or update) one entry in memory; call [`Self::save`] to
    /// persist. `arch` is the exact config the model was compiled
    /// with, so a restart republishes the same mapping.
    pub fn record(
        &self,
        name: &str,
        zoo: &str,
        seed: Option<u64>,
        version: u64,
        arch: Option<ArchConfig>,
    ) {
        self.entries.lock().unwrap().insert(
            name.to_string(),
            ManifestEntry {
                zoo: zoo.to_string(),
                seed,
                version,
                arch,
            },
        );
    }

    /// Drop one entry in memory; call [`Self::save`] to persist.
    pub fn remove(&self, name: &str) {
        self.entries.lock().unwrap().remove(name);
    }

    /// Atomically rewrite the manifest file (write temp + rename, so a
    /// crash mid-write never leaves a truncated manifest). The entries
    /// lock is held across encode + write + rename: concurrent admin
    /// dispatches share one temp file, and unsynchronized writers
    /// could interleave bytes and publish a mangled manifest.
    pub fn save(&self) -> Result<()> {
        let entries = self.entries.lock().unwrap();
        let text = super::wire::encode(&Self::entries_to_json(&entries));
        let tmp = self.path.with_extension("tmp");
        std::fs::write(&tmp, text.as_bytes())
            .with_context(|| format!("write registry manifest {}", tmp.display()))?;
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("publish registry manifest {}", self.path.display()))?;
        Ok(())
    }

    /// Replay every entry into `registry` (recompiling each model from
    /// its recorded zoo name and seed at its recorded version and
    /// recorded per-model arch — `default_arch` is used only for
    /// legacy entries that predate mapping persistence). Names already
    /// loaded are left untouched. Returns how many models were
    /// restored.
    pub fn restore(&self, registry: &ModelRegistry, default_arch: ArchConfig) -> Result<usize> {
        let entries = self.entries.lock().unwrap().clone();
        let mut restored = 0;
        for (name, e) in &entries {
            if registry.get(name).is_some() {
                continue;
            }
            let net = zoo::lookup(&e.zoo)
                .with_context(|| format!("restore manifest entry {name:?}"))?;
            registry
                .load_restored(name, &net, e.arch.unwrap_or(default_arch), e.seed, e.version)
                .with_context(|| format!("restore manifest entry {name:?}"))?;
            restored += 1;
        }
        Ok(restored)
    }
}

/// Default cap on concurrently executing `Request::Trace` dispatches
/// (see [`Service::with_trace_budget`]).
pub const DEFAULT_TRACE_BUDGET: usize = 2;

/// Image seed for the diagnostic run `FaultInject` performs when it
/// arms a plan. Fixed (not caller-chosen): the diagnostic is a smoke
/// signal, and a stable seed makes its verdict reproducible across
/// arms of the same plan.
pub const FAULT_DIAG_SEED: u64 = 0xFA_17;

/// Observer of every dispatched request/response pair — the
/// `Probe`-style hook the traffic recorder (`serve::traffic`) arms on
/// a live service. The tap sees the request *after* dispatch decided
/// the response, on the dispatching thread, for local and TCP callers
/// alike (there is only one dispatch path). Implementations must be
/// cheap and must not dispatch back into the service.
pub trait DispatchTap: Send + Sync {
    fn on_dispatch(&self, req: &Request, resp: &Response);
}

/// The dispatch surface `serve::net` serves and `serve::cluster`
/// composes: anything that executes one typed [`Request`] into one
/// [`Response`] (failures folded into [`Response::Error`], never
/// `Err`). [`Service`] is the leaf implementation — one process's
/// registry and worker pool; `serve::cluster::Router` implements it by
/// routing to many remote services over the same wire protocol. A TCP
/// endpoint fronts either without knowing which: a remote call is the
/// same call, one level up.
pub trait Dispatcher: Send + Sync + 'static {
    /// Execute one typed request.
    fn dispatch(&self, req: Request) -> Response;

    /// Record one connection refused over capacity at the TCP accept
    /// loop, where the implementation keeps a counter (default no-op).
    fn note_conn_refused(&self) {}
}

impl Dispatcher for Service {
    fn dispatch(&self, req: Request) -> Response {
        Service::dispatch(self, req)
    }

    fn note_conn_refused(&self) {
        Service::note_conn_refused(self)
    }
}

/// The one front door for every plane: wraps a running [`Server`] and
/// dispatches typed [`Request`]s, locally or (through `serve::net`)
/// over TCP. Admin mutations optionally persist through a
/// [`RegistryManifest`].
pub struct Service {
    server: Server,
    arch: ArchConfig,
    manifest: Option<Arc<RegistryManifest>>,
    /// Cap on concurrently executing traces. A trace runs a full
    /// instrumented cycle-sim *inline on the dispatching thread* —
    /// it never passes through the bounded data-plane queue — so
    /// without a budget N hostile connections could run N unbounded
    /// simulations while paid inference traffic starves.
    trace_budget: usize,
    /// Traces currently executing (bounded by `trace_budget`).
    trace_live: AtomicUsize,
    /// Traces rejected by the budget (surfaced in [`StatsReply`]).
    trace_rejected: AtomicU64,
    /// Connections refused over capacity by the TCP accept loop
    /// (`serve::net` reports in via [`Self::note_conn_refused`]).
    conns_refused: AtomicU64,
    /// Optional dispatch observer (see [`DispatchTap`]); armed by the
    /// traffic recorder, `None` in the steady state.
    tap: Mutex<Option<Arc<dyn DispatchTap>>>,
    /// Armed fault plans by model name (the fault plane). A plan stays
    /// armed across swaps and re-maps — it models broken *hardware*,
    /// keyed by physical coordinates, so a re-mapped model simply stops
    /// touching the bad sites.
    faults: Mutex<BTreeMap<String, FaultPlan>>,
}

/// RAII slot in the trace budget: acquired lock-free at the top of
/// `do_trace`, released on every exit path (including errors) by Drop.
struct TracePermit<'a> {
    live: &'a AtomicUsize,
}

impl<'a> TracePermit<'a> {
    fn acquire(live: &'a AtomicUsize, budget: usize) -> Option<Self> {
        let mut cur = live.load(Ordering::Relaxed);
        loop {
            if cur >= budget {
                return None;
            }
            match live.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Relaxed) {
                Ok(_) => return Some(Self { live }),
                Err(now) => cur = now,
            }
        }
    }
}

impl Drop for TracePermit<'_> {
    fn drop(&mut self) {
        self.live.fetch_sub(1, Ordering::AcqRel);
    }
}

impl Service {
    pub fn new(server: Server, arch: ArchConfig) -> Self {
        Self {
            server,
            arch,
            manifest: None,
            trace_budget: DEFAULT_TRACE_BUDGET,
            trace_live: AtomicUsize::new(0),
            trace_rejected: AtomicU64::new(0),
            conns_refused: AtomicU64::new(0),
            tap: Mutex::new(None),
            faults: Mutex::new(BTreeMap::new()),
        }
    }

    /// [`Self::new`], persisting every API-plane registry mutation to
    /// `manifest` (see [`RegistryManifest`]).
    pub fn with_manifest(server: Server, arch: ArchConfig, manifest: Arc<RegistryManifest>) -> Self {
        Self {
            manifest: Some(manifest),
            ..Self::new(server, arch)
        }
    }

    /// Override the concurrent-trace budget (`n = 0` rejects every
    /// trace — useful to make shedding deterministic in tests).
    pub fn with_trace_budget(mut self, n: usize) -> Self {
        self.trace_budget = n;
        self
    }

    /// Record one refused-over-capacity connection. Called by the TCP
    /// accept loop so connection-level shedding shows up in `Stats`.
    pub fn note_conn_refused(&self) {
        self.conns_refused.fetch_add(1, Ordering::Relaxed);
    }

    /// Total connections refused over capacity at the TCP endpoint.
    pub fn conns_refused(&self) -> u64 {
        self.conns_refused.load(Ordering::Relaxed)
    }

    /// Total traces rejected by the concurrent-trace budget.
    pub fn trace_rejected(&self) -> u64 {
        self.trace_rejected.load(Ordering::Relaxed)
    }

    /// Arm a [`DispatchTap`]: from now on every dispatched
    /// request/response pair is observed (replacing any earlier tap).
    pub fn set_tap(&self, tap: Arc<dyn DispatchTap>) {
        *self.tap.lock().unwrap() = Some(tap);
    }

    /// Disarm the dispatch tap (dispatch reverts to zero overhead
    /// beyond one uncontended mutex probe).
    pub fn clear_tap(&self) {
        *self.tap.lock().unwrap() = None;
    }

    /// The wrapped server (counters, registry, direct submit paths).
    pub fn server(&self) -> &Server {
        &self.server
    }

    /// Shut the wrapped server down (graceful drain; see
    /// `Server::shutdown`).
    pub fn shutdown(self) -> Result<Vec<u64>> {
        self.server.shutdown()
    }

    /// Execute one typed request. This is the single entry point both
    /// the in-process path and the TCP endpoint use; failures become
    /// [`Response::Error`], never `Err`.
    pub fn dispatch(&self, req: Request) -> Response {
        // clone the tap handle out of the lock so a slow observer
        // never holds up other dispatching threads; clone the request
        // only while a recorder is actually armed
        let tap = self.tap.lock().unwrap().clone();
        let recorded_req = tap.as_ref().map(|_| req.clone());
        let r = match req {
            Request::Infer { model, image } => self.do_infer(model, image),
            Request::Load { model, mapping } => self.do_load(&model, None, mapping.as_ref()),
            Request::LoadSeeded {
                model,
                seed,
                mapping,
            } => self.do_load(&model, Some(seed), mapping.as_ref()),
            Request::Swap { model, seed } => self.do_swap(&model, seed),
            Request::Unload { model } => self.do_unload(&model),
            Request::ListModels => self.do_list(),
            Request::ModelInfo { model } => self.do_info(&model),
            Request::Stats => Ok(self.do_stats()),
            Request::Trace {
                model,
                image_seed,
                window,
            } => self.do_trace(&model, image_seed, window),
            Request::FaultInject { model, plan } => self.do_fault_inject(&model, &plan),
            Request::Canary { model, seed, heal } => self.do_canary(&model, seed, heal),
        };
        let resp = r.unwrap_or_else(|e| Response::Error {
            message: format!("{e:#}"),
        });
        if let (Some(tap), Some(req)) = (tap, recorded_req) {
            tap.on_dispatch(&req, &resp);
        }
        resp
    }

    fn registry(&self) -> Result<&Arc<ModelRegistry>> {
        self.server.registry().ok_or_else(|| {
            anyhow!(
                "the {} backend has no model registry (admin and model \
                 requests need the sim backend)",
                self.server.backend()
            )
        })
    }

    /// Resolve a user-supplied model name to the registry key it is
    /// published under. An exact registry match wins (a prebuilt model
    /// may be published under a name that happens to alias a zoo
    /// entry); otherwise zoo names are canonicalized (`TINY_CNN` →
    /// `tiny-cnn`), and unknown names pass through so registry errors
    /// can list what *is* loaded. Borrowed (allocation-free) in the
    /// common already-canonical case; the registry probe here plus
    /// the lookup inside the eventual registry operation is two cheap
    /// uncontended read-lock hits — noise next to a cycle-accurate
    /// image simulation.
    fn registry_key<'a>(&self, model: &'a str) -> std::borrow::Cow<'a, str> {
        use std::borrow::Cow;
        if let Some(reg) = self.server.registry() {
            if reg.get(model).is_some() {
                return Cow::Borrowed(model);
            }
        }
        match zoo::by_name(model) {
            Some(net) => Cow::Owned(net.name),
            None => Cow::Borrowed(model),
        }
    }

    fn persist(&self) -> Result<()> {
        match &self.manifest {
            Some(m) => m
                .save()
                .context("registry mutation applied, but the manifest write failed"),
            None => Ok(()),
        }
    }

    fn do_infer(&self, model: Option<String>, image: Vec<i8>) -> Result<Response> {
        // canonicalize like every other plane, so the name that
        // worked for Load/ModelInfo also works for Infer
        let key = model.map(|m| self.registry_key(&m).into_owned());
        if let Some(faulty) = self.infer_faulty(key.as_deref(), &image)? {
            return Ok(faulty);
        }
        let r = match &key {
            Some(k) => self.server.infer_on(k, image)?,
            None => self.server.infer(image)?,
        };
        Ok(Response::Infer(InferReply {
            logits: r.logits,
            model: r.model,
            queue_us: r.queue.as_micros() as u64,
            exec_us: r.exec.as_micros() as u64,
        }))
    }

    /// The model name an infer for `model` resolves to, if the fault
    /// plane has a plan armed for it (`None` routes to the sole model,
    /// exactly like `Server::submit`).
    fn armed_plan(&self, model: Option<&str>) -> Option<(String, FaultPlan)> {
        let faults = self.faults.lock().unwrap();
        if faults.is_empty() {
            return None;
        }
        let name = match model {
            Some(m) => m.to_string(),
            None => self.server.registry()?.sole()?.name().to_string(),
        };
        let plan = faults.get(&name)?.clone();
        Some((name, plan))
    }

    /// Serve one inference through a fault-injecting engine when a
    /// plan is armed for the target model. Runs inline on the
    /// dispatching thread (like a trace): corruption must be
    /// deterministic per request, and the pooled worker engines must
    /// stay pristine for the other models. Counts as served traffic —
    /// to a client this *is* the data plane, silently wrong and all.
    fn infer_faulty(&self, model: Option<&str>, image: &[i8]) -> Result<Option<Response>> {
        let Some((name, plan)) = self.armed_plan(model) else {
            return Ok(None);
        };
        let reg = self.registry()?;
        let mv = reg.get(&name).ok_or_else(|| {
            anyhow!(
                "model {name:?} is not loaded (loaded: [{}])",
                reg.names().join(", ")
            )
        })?;
        anyhow::ensure!(
            image.len() == mv.input_len(),
            "image for model {name:?} must be {} int8 values (got {})",
            mv.input_len(),
            image.len()
        );
        let t0 = Instant::now();
        let mut sim = Simulator::with_faults(mv.program(), plan);
        let out = sim.run_image(image).context("fault-injected simulation")?;
        let exec = t0.elapsed();
        self.server.note_fault_serve(mv.name(), exec);
        Ok(Some(Response::Infer(InferReply {
            logits: out.scores,
            model: Some(mv.stamp()),
            queue_us: 0,
            exec_us: exec.as_micros() as u64,
        })))
    }

    fn do_load(
        &self,
        model: &str,
        seed: Option<u64>,
        mapping: Option<&MappingSpec>,
    ) -> Result<Response> {
        let reg = self.registry()?;
        let net = zoo::lookup(model)?;
        let arch = match mapping {
            Some(spec) => spec.apply(self.arch)?,
            None => self.arch,
        };
        let mv = reg.load_seeded(&net.name, &net, arch, seed)?;
        if let Some(man) = &self.manifest {
            man.record(&net.name, &net.name, seed, mv.version(), Some(arch));
        }
        self.persist()?;
        Ok(Response::Loaded(mv.stamp()))
    }

    fn do_swap(&self, model: &str, seed: Option<u64>) -> Result<Response> {
        let reg = self.registry()?;
        let net = zoo::lookup(model)?;
        // a swap preserves the model's current per-model mapping —
        // recompiling at the service-wide default would silently
        // re-map a model loaded with a custom one
        let arch = reg
            .get(&net.name)
            .map(|mv| mv.program().arch)
            .unwrap_or(self.arch);
        let mv = reg.swap_seeded(&net.name, &net, arch, seed)?;
        if let Some(man) = &self.manifest {
            man.record(&net.name, &net.name, seed, mv.version(), Some(arch));
        }
        self.persist()?;
        Ok(Response::Swapped(mv.stamp()))
    }

    fn do_unload(&self, model: &str) -> Result<Response> {
        let reg = self.registry()?;
        let key = self.registry_key(model);
        let mv = reg.unload(&key)?;
        if let Some(man) = &self.manifest {
            man.remove(&key);
        }
        self.persist()?;
        Ok(Response::Unloaded(mv.stamp()))
    }

    fn do_list(&self) -> Result<Response> {
        let reg = self.registry()?;
        let descs: Vec<ModelDesc> = reg
            .list()
            .iter()
            .map(|mv| ModelDesc::of_version(mv))
            .collect::<Result<_>>()?;
        Ok(Response::Models(descs))
    }

    fn do_info(&self, model: &str) -> Result<Response> {
        let reg = self.registry()?;
        let key = self.registry_key(model);
        let mv = reg.get(&key).ok_or_else(|| {
            anyhow!(
                "model {model:?} is not loaded (loaded: [{}])",
                reg.names().join(", ")
            )
        })?;
        Ok(Response::Info(ModelDesc::of_version(&mv)?))
    }

    fn do_stats(&self) -> Response {
        Response::Stats(StatsReply {
            served: self.server.served(),
            rejected: self.server.rejected(),
            failed: self.server.failed(),
            conns_refused: self.conns_refused.load(Ordering::Relaxed),
            trace_rejected: self.trace_rejected.load(Ordering::Relaxed),
            models: self.server.metrics_snapshot(),
        })
    }

    fn do_trace(&self, model: &str, image_seed: u64, window: u64) -> Result<Response> {
        // Budget first: a trace is an inline instrumented cycle-sim on
        // *this* thread, outside the bounded data-plane queue, so it
        // needs its own backpressure. Over budget is a typed overload
        // error (load-shedding), never a wait.
        let _permit = match TracePermit::acquire(&self.trace_live, self.trace_budget) {
            Some(p) => p,
            None => {
                self.trace_rejected.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!(
                    "trace budget exhausted ({} concurrent traces): \
                     the observability plane is shedding load, retry later",
                    self.trace_budget
                );
            }
        };
        let reg = self.registry()?;
        let key = self.registry_key(model);
        let mv = reg.get(&key).ok_or_else(|| {
            anyhow!(
                "model {model:?} is not loaded (loaded: [{}])",
                reg.names().join(", ")
            )
        })?;
        let program = mv.program();
        // A fresh instrumented engine per trace: traces are a
        // diagnostic plane, not the serving hot path, and recordings
        // must not bleed between requests.
        let mut sim = Simulator::with_recorder(program, RecorderConfig::default());
        let mut rng = Rng::new(image_seed);
        let out = sim
            .run_image(&rng.i8_vec(program.net.input_len(), 31))
            .context("traced simulation")?;
        let rec = sim.recording();
        let heatmap = LinkHeatmap::busiest_stage(&rec)
            .and_then(|si| LinkHeatmap::build(&rec, si, 40))
            .map(|h| h.render())
            .unwrap_or_default();
        let window = (window as usize).min(rec.events.len());
        self.server.note_trace(&key);
        Ok(Response::Trace(TraceReply {
            model: mv.stamp(),
            image_seed,
            events_total: rec.events.len() as u64,
            dropped: rec.dropped,
            events: rec.events[..window].to_vec(),
            scores: out.scores,
            heatmap,
        }))
    }

    fn do_fault_inject(&self, model: &str, plan: &str) -> Result<Response> {
        let reg = self.registry()?;
        let key = self.registry_key(model).into_owned();
        let mv = reg.get(&key).ok_or_else(|| {
            anyhow!(
                "model {model:?} is not loaded (loaded: [{}])",
                reg.names().join(", ")
            )
        })?;
        let plan = FaultPlan::parse(plan).context("fault plan")?;
        if plan.is_empty() {
            self.faults.lock().unwrap().remove(&key);
            return Ok(Response::Fault(FaultReply {
                model: mv.stamp(),
                armed: false,
                sites: 0,
                fires: 0,
                lanes: 0,
                corrupted: false,
                mismatched: 0,
                outputs: 0,
                report: String::new(),
            }));
        }
        self.faults
            .lock()
            .unwrap()
            .insert(key.clone(), plan.clone());
        // one diagnostic run under the plan: does it fire, and does it
        // corrupt? (a site the mapping never exercises is armed but
        // silent — worth telling the operator up front)
        let sites = plan.len() as u64;
        let img = Rng::new(FAULT_DIAG_SEED).i8_vec(mv.input_len(), 31);
        let mut sim = Simulator::with_faults(mv.program(), plan);
        let out = sim
            .run_image(&img)
            .context("fault-injected diagnostic run")?;
        let report = sim.fault_report();
        let verdict = corruption_verdict(&out.scores, &mv.refcompute(&img)?);
        Ok(Response::Fault(FaultReply {
            model: mv.stamp(),
            armed: true,
            sites,
            fires: report.total_fires(),
            lanes: report.total_lanes(),
            corrupted: verdict.corrupted,
            mismatched: verdict.mismatched as u64,
            outputs: verdict.outputs as u64,
            report: report.render(),
        }))
    }

    fn do_canary(&self, model: &str, seed: u64, heal: bool) -> Result<Response> {
        let reg = self.registry()?;
        let key = self.registry_key(model).into_owned();
        let mv = reg.get(&key).ok_or_else(|| {
            anyhow!(
                "model {model:?} is not loaded (loaded: [{}])",
                reg.names().join(", ")
            )
        })?;
        let img = Rng::new(seed).i8_vec(mv.input_len(), 31);
        let oracle = mv.refcompute(&img)?;
        // through the same data plane a client uses — armed fault
        // plans included — so silent corruption is what gets checked
        let got = match self.do_infer(Some(key.clone()), img.clone())? {
            Response::Infer(r) => r.logits,
            other => anyhow::bail!("canary infer returned {other:?}"),
        };
        let verdict = corruption_verdict(&got, &oracle);
        let ok = !verdict.corrupted;
        self.server.set_degraded(&key, !ok);
        let mut remapped = false;
        let mut healed = false;
        if !ok && heal {
            // Re-map around the armed plan's physical fault sites. The
            // plan stays armed — it models broken hardware — but the
            // re-mapped program never touches the masked coordinates,
            // so the very same injected faults stop firing.
            if let Some((_, plan)) = self.armed_plan(Some(&key)) {
                let mask = TileMask::from_coords(plan.coords());
                reg.remap_masked(&key, &mask)
                    .context("fault-plane re-map")?;
                remapped = true;
                let again = match self.do_infer(Some(key.clone()), img.clone())? {
                    Response::Infer(r) => r.logits,
                    other => anyhow::bail!("canary re-check returned {other:?}"),
                };
                // weights survive the re-map bit-exactly, so the old
                // oracle still judges the new version
                healed = !corruption_verdict(&again, &oracle).corrupted;
                self.server.set_degraded(&key, !healed);
            }
        }
        let version = reg.get(&key).map(|v| v.version()).unwrap_or(0);
        Ok(Response::Canary(CanaryReply {
            model: mv.stamp(),
            ok,
            mismatched: verdict.mismatched as u64,
            outputs: verdict.outputs as u64,
            remapped,
            healed,
            version,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::ServeConfig;

    fn start_service() -> Service {
        let registry = Arc::new(ModelRegistry::new());
        let net = zoo::tiny_mlp();
        registry.load(&net.name, &net, ArchConfig::default()).unwrap();
        let server = Server::start_multi(
            ServeConfig {
                workers: 1,
                max_batch: 4,
                queue_cap: 64,
                ..ServeConfig::default()
            },
            registry,
        )
        .unwrap();
        Service::new(server, ArchConfig::default())
    }

    #[test]
    fn dispatch_covers_all_three_planes_and_matches_inprocess() {
        let service = start_service();

        // admin plane: load a second model by (case-insensitive) name
        let stamp = match service.dispatch(Request::LoadSeeded {
            model: "TINY_RESNET".into(),
            seed: 0xAB,
            mapping: None,
        }) {
            Response::Loaded(s) => s,
            other => panic!("expected Loaded, got {other:?}"),
        };
        assert_eq!(&*stamp.name, "tiny-resnet");
        assert_eq!(stamp.version, 1);

        // observability plane: both models described
        let models = match service.dispatch(Request::ListModels) {
            Response::Models(m) => m,
            other => panic!("expected Models, got {other:?}"),
        };
        let names: Vec<&str> = models.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(names, ["tiny-mlp", "tiny-resnet"]);
        assert!(models.iter().all(|d| d.params > 0 && d.macs > 0));

        // data plane: dispatch(Infer) is the same call as infer_on —
        // same model version stamp, same logits
        let mv = service
            .server()
            .registry()
            .unwrap()
            .get("tiny-resnet")
            .unwrap();
        let image = vec![5i8; mv.input_len()];
        let reply = match service.dispatch(Request::Infer {
            model: Some("tiny-resnet".into()),
            image: image.clone(),
        }) {
            Response::Infer(r) => r,
            other => panic!("expected Infer, got {other:?}"),
        };
        let direct = service.server().infer_on("tiny-resnet", image.clone()).unwrap();
        assert_eq!(reply.logits, direct.logits);
        assert_eq!(reply.model.as_ref(), direct.model.as_ref());
        assert_eq!(reply.logits, mv.refcompute(&image).unwrap());

        // swap bumps the stamp; infer after swap runs the new version
        let swapped = match service.dispatch(Request::Swap {
            model: "tiny-resnet".into(),
            seed: Some(0xCD),
        }) {
            Response::Swapped(s) => s,
            other => panic!("expected Swapped, got {other:?}"),
        };
        assert_eq!(swapped.version, 2);
        let reply2 = match service.dispatch(Request::Infer {
            model: Some("tiny-resnet".into()),
            image: image.clone(),
        }) {
            Response::Infer(r) => r,
            other => panic!("expected Infer, got {other:?}"),
        };
        assert_eq!(reply2.model.as_ref().unwrap().version, 2);
        let mv2 = service
            .server()
            .registry()
            .unwrap()
            .get("tiny-resnet")
            .unwrap();
        assert_eq!(reply2.logits, mv2.refcompute(&image).unwrap());

        // stats plane: per-model entries with counts and percentiles
        let stats = match service.dispatch(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("expected Stats, got {other:?}"),
        };
        assert_eq!(stats.served, 3);
        let resnet = stats
            .models
            .iter()
            .find(|m| m.model == "tiny-resnet")
            .expect("per-model stats entry");
        assert_eq!(resnet.served, 3, "all three infers targeted tiny-resnet");
        assert!(resnet.p50_us.is_some());

        // unload, then errors are typed — never panics or Err
        match service.dispatch(Request::Unload {
            model: "tiny-resnet".into(),
        }) {
            Response::Unloaded(s) => assert_eq!(&*s.name, "tiny-resnet"),
            other => panic!("expected Unloaded, got {other:?}"),
        }
        match service.dispatch(Request::Infer {
            model: Some("tiny-resnet".into()),
            image,
        }) {
            Response::Error { message } => {
                assert!(message.contains("tiny-mlp"), "{message}")
            }
            other => panic!("expected Error, got {other:?}"),
        }
        match service.dispatch(Request::ModelInfo {
            model: "nope".into(),
        }) {
            Response::Error { message } => {
                assert!(message.contains("not loaded") || message.contains("unknown"), "{message}")
            }
            other => panic!("expected Error, got {other:?}"),
        }

        service.shutdown().unwrap();
    }

    /// The fault plane end-to-end: arm a stuck tile → the data plane
    /// serves silently-wrong (structurally valid, bit-wrong) responses
    /// → a canary detects it and marks the model degraded → a healing
    /// canary re-maps around the bad tile (fault still armed) → every
    /// post-recovery response is refcompute-bit-exact.
    #[test]
    fn fault_plane_detects_and_heals_silent_corruption() {
        let service = start_service();
        let reg = Arc::clone(service.server().registry().unwrap());
        let mv = reg.get("tiny-mlp").unwrap();
        let bad = mv.program().tile_coords()[0];
        let plan = FaultPlan::new().stuck_tile(bad, 7).spec();

        // arm: the diagnostic run fires and corrupts
        let fr = match service.dispatch(Request::FaultInject {
            model: "tiny-mlp".into(),
            plan,
        }) {
            Response::Fault(f) => f,
            other => panic!("expected Fault, got {other:?}"),
        };
        assert!(fr.armed);
        assert_eq!(fr.sites, 1);
        assert!(fr.fires > 0, "the site sits on a mapped tile: it must fire");
        assert!(fr.corrupted, "a stuck tile must corrupt the scores");
        assert!(fr.report.contains("stuck"), "{}", fr.report);

        // the data plane now serves silently-wrong responses
        let img = Rng::new(42).i8_vec(mv.input_len(), 31);
        let oracle = mv.refcompute(&img).unwrap();
        let reply = match service.dispatch(Request::Infer {
            model: Some("tiny-mlp".into()),
            image: img.clone(),
        }) {
            Response::Infer(r) => r,
            other => panic!("expected Infer, got {other:?}"),
        };
        assert_eq!(reply.logits.len(), oracle.len(), "structurally valid");
        assert_ne!(reply.logits, oracle, "bit-wrong: the silent corruption");

        // canary without heal: detects and marks degraded
        let c = match service.dispatch(Request::Canary {
            model: "tiny-mlp".into(),
            seed: 42,
            heal: false,
        }) {
            Response::Canary(c) => c,
            other => panic!("expected Canary, got {other:?}"),
        };
        assert!(!c.ok && !c.remapped && !c.healed);
        assert!(c.mismatched > 0 && c.outputs > 0);
        let stats = match service.dispatch(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("expected Stats, got {other:?}"),
        };
        let m = stats.models.iter().find(|m| m.model == "tiny-mlp").unwrap();
        assert!(m.degraded, "a failed canary must mark the model degraded");

        // canary with heal: re-map around the bad tile; the plan stays
        // armed (broken hardware does not un-break), the new placement
        // just never touches it
        let c = match service.dispatch(Request::Canary {
            model: "tiny-mlp".into(),
            seed: 42,
            heal: true,
        }) {
            Response::Canary(c) => c,
            other => panic!("expected Canary, got {other:?}"),
        };
        assert!(!c.ok, "the pre-heal check still sees the corruption");
        assert!(c.remapped && c.healed);
        assert_eq!(c.version, 2, "heal publishes a re-mapped version");
        let healed_mv = reg.get("tiny-mlp").unwrap();
        assert!(
            healed_mv.program().tile_coords().iter().all(|&t| t != bad),
            "the re-mapped program must avoid the masked tile"
        );

        // post-recovery: bit-exact responses on the new version, flag
        // cleared — with the fault STILL armed
        let reply = match service.dispatch(Request::Infer {
            model: Some("tiny-mlp".into()),
            image: img.clone(),
        }) {
            Response::Infer(r) => r,
            other => panic!("expected Infer, got {other:?}"),
        };
        assert_eq!(reply.logits, oracle, "post-heal responses are bit-exact");
        assert_eq!(reply.model.unwrap().version, 2);
        let stats = match service.dispatch(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("expected Stats, got {other:?}"),
        };
        let m = stats.models.iter().find(|m| m.model == "tiny-mlp").unwrap();
        assert!(!m.degraded, "a clean heal must clear the degraded flag");

        // disarm with the empty plan
        match service.dispatch(Request::FaultInject {
            model: "tiny-mlp".into(),
            plan: String::new(),
        }) {
            Response::Fault(f) => assert!(!f.armed),
            other => panic!("expected Fault, got {other:?}"),
        }
        // a site spec that does not parse is a typed error
        match service.dispatch(Request::FaultInject {
            model: "tiny-mlp".into(),
            plan: "tile:bogus".into(),
        }) {
            Response::Error { message } => assert!(message.contains("fault"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        service.shutdown().unwrap();
    }

    #[test]
    fn manifest_roundtrips_and_restores_exact_versions() {
        let path = std::env::temp_dir().join(format!(
            "domino-manifest-test-{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);

        // first life: load + swap through the manifest
        let man = RegistryManifest::open(&path).unwrap();
        assert!(man.is_empty());
        man.record("tiny-mlp", "tiny-mlp", Some(0xAA), 1, Some(ArchConfig::default()));
        man.record("tiny-resnet", "tiny-resnet", None, 3, None);
        man.save().unwrap();
        assert!(path.exists());

        // second life: reopen and replay into a fresh registry
        let man2 = RegistryManifest::open(&path).unwrap();
        assert_eq!(man2.len(), 2);
        let registry = ModelRegistry::new();
        let restored = man2.restore(&registry, ArchConfig::default()).unwrap();
        assert_eq!(restored, 2);
        let mlp = registry.get("tiny-mlp").unwrap();
        assert_eq!(mlp.version(), 1);
        let resnet = registry.get("tiny-resnet").unwrap();
        assert_eq!(resnet.version(), 3, "version survives the restart");

        // the restored weights are the same pure function of the seed
        let direct = ModelRegistry::new();
        let want = direct
            .load_seeded("tiny-mlp", &zoo::tiny_mlp(), ArchConfig::default(), Some(0xAA))
            .unwrap();
        let img = vec![7i8; mlp.input_len()];
        assert_eq!(mlp.refcompute(&img).unwrap(), want.refcompute(&img).unwrap());

        // restore skips names that are already loaded
        assert_eq!(man2.restore(&registry, ArchConfig::default()).unwrap(), 0);

        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_with_mapping_applies_and_reports_it() {
        let service = start_service();
        let spec = MappingSpec {
            pooling: Some(PoolingScheme::WeightDuplication),
            placement: Some(Placement::ColumnMajor),
            mesh_cols: Some(12),
            chip_aligned: Some(true),
            sync_chips: None,
        };
        match service.dispatch(Request::LoadSeeded {
            model: "tiny-cnn".into(),
            seed: 0x99,
            mapping: Some(spec),
        }) {
            Response::Loaded(st) => assert_eq!(&*st.name, "tiny-cnn"),
            other => panic!("expected Loaded, got {other:?}"),
        }
        let mv = service
            .server()
            .registry()
            .unwrap()
            .get("tiny-cnn")
            .unwrap();
        let arch = mv.program().arch;
        assert_eq!(arch.pooling, PoolingScheme::WeightDuplication);
        assert_eq!(arch.placement, Placement::ColumnMajor);
        assert_eq!(arch.mesh_cols, 12);
        assert!(arch.chip_aligned_chains);

        // the mapped model still serves refcompute-exact responses
        let image = vec![2i8; mv.input_len()];
        match service.dispatch(Request::Infer {
            model: Some("tiny-cnn".into()),
            image: image.clone(),
        }) {
            Response::Infer(r) => assert_eq!(r.logits, mv.refcompute(&image).unwrap()),
            other => panic!("expected Infer, got {other:?}"),
        }

        // ModelInfo reports the mapping + placement stats
        let info = match service.dispatch(Request::ModelInfo {
            model: "tiny-cnn".into(),
        }) {
            Response::Info(d) => d,
            other => panic!("expected Info, got {other:?}"),
        };
        let m = info.mapping.expect("live models report their mapping");
        assert_eq!(m.pooling, "weight-duplication");
        assert_eq!(m.placement, "column-major");
        assert_eq!(m.mesh_cols, 12);
        assert!(m.chip_aligned);
        assert_eq!(m.tiles, mv.program().total_tiles as u64);
        assert!(m.images_per_s > 0 && m.pj_per_image > 0);

        // a swap keeps the custom mapping instead of re-applying the
        // service default
        match service.dispatch(Request::Swap {
            model: "tiny-cnn".into(),
            seed: Some(0xA1),
        }) {
            Response::Swapped(st) => assert_eq!(st.version, 2),
            other => panic!("expected Swapped, got {other:?}"),
        }
        let mv2 = service
            .server()
            .registry()
            .unwrap()
            .get("tiny-cnn")
            .unwrap();
        assert_eq!(mv2.program().arch, arch, "swap must preserve the mapping");

        // a geometry that cannot fit is a typed error, not a panic
        match service.dispatch(Request::Load {
            model: "tiny-mlp".into(),
            mapping: Some(MappingSpec {
                mesh_cols: Some(100_000),
                ..MappingSpec::default()
            }),
        }) {
            Response::Error { message } => assert!(message.contains("mesh_cols"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        // ...and so is a sync budget whose tile arithmetic would
        // overflow (hostile wire input must never panic the server)
        match service.dispatch(Request::Load {
            model: "tiny-mlp".into(),
            mapping: Some(MappingSpec {
                sync_chips: Some(u64::MAX),
                ..MappingSpec::default()
            }),
        }) {
            Response::Error { message } => assert!(message.contains("sync_chips"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }

        service.shutdown().unwrap();
    }

    #[test]
    fn trace_is_deterministic_and_cross_checks_scores() {
        let service = start_service();
        let req = Request::Trace {
            model: "tiny-mlp".into(),
            image_seed: 7,
            window: 16,
        };
        let reply = match service.dispatch(req.clone()) {
            Response::Trace(t) => t,
            other => panic!("expected Trace, got {other:?}"),
        };
        assert_eq!(&*reply.model.name, "tiny-mlp");
        assert!(reply.events_total > 0, "a traced run records events");
        assert_eq!(
            reply.events.len(),
            16usize.min(reply.events_total as usize),
            "window cuts the stream"
        );
        assert!(reply.heatmap.contains("link utilization"), "{}", reply.heatmap);

        // the traced run computed the right thing: scores match
        // refcompute on the same seeded image
        let mv = service
            .server()
            .registry()
            .unwrap()
            .get("tiny-mlp")
            .unwrap();
        let img = Rng::new(7).i8_vec(mv.input_len(), 31);
        assert_eq!(reply.scores, mv.refcompute(&img).unwrap());

        // same seed, same recording prefix — traces are deterministic
        let again = match service.dispatch(req) {
            Response::Trace(t) => t,
            other => panic!("expected Trace, got {other:?}"),
        };
        assert_eq!(reply, again);

        // per-model metrics count the traces
        let snap = service
            .server()
            .metrics_snapshot()
            .into_iter()
            .find(|m| m.model == "tiny-mlp")
            .unwrap();
        assert_eq!(snap.traced, 2);

        // unknown model is a typed error
        match service.dispatch(Request::Trace {
            model: "nope".into(),
            image_seed: 1,
            window: 4,
        }) {
            Response::Error { message } => assert!(message.contains("not loaded"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        service.shutdown().unwrap();
    }

    /// The satellite regression: two models at *different* mappings
    /// must restore at their own mappings, not the service-wide
    /// default (the old manifest dropped the per-model arch entirely).
    #[test]
    fn manifest_restores_two_models_at_their_own_mappings() {
        let path = std::env::temp_dir().join(format!(
            "domino-manifest-mapping-test-{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);

        let default_arch = ArchConfig::default();
        let mut custom = default_arch;
        custom.pooling = PoolingScheme::WeightDuplication;
        custom.placement = Placement::ColumnMajor;
        custom.mesh_cols = 12;

        let man = RegistryManifest::open(&path).unwrap();
        man.record("tiny-cnn", "tiny-cnn", Some(0x1), 1, Some(custom));
        man.record("tiny-resnet", "tiny-resnet", Some(0x2), 2, Some(default_arch));
        man.save().unwrap();

        let man2 = RegistryManifest::open(&path).unwrap();
        let registry = ModelRegistry::new();
        assert_eq!(man2.restore(&registry, default_arch).unwrap(), 2);
        let cnn = registry.get("tiny-cnn").unwrap();
        let resnet = registry.get("tiny-resnet").unwrap();
        assert_eq!(
            cnn.program().arch, custom,
            "custom mapping must survive the restart"
        );
        assert_eq!(resnet.program().arch, default_arch);
        assert_ne!(cnn.program().arch, resnet.program().arch);
        assert_eq!(resnet.version(), 2);

        // and the restored custom-mapped model is the same pure
        // function of (net, seed, arch): weights + outputs bit-equal
        let direct = ModelRegistry::new();
        let want = direct
            .load_seeded("tiny-cnn", &zoo::tiny_cnn(), custom, Some(0x1))
            .unwrap();
        let img = vec![4i8; cnn.input_len()];
        assert_eq!(cnn.refcompute(&img).unwrap(), want.refcompute(&img).unwrap());

        let _ = std::fs::remove_file(&path);
    }
}
