//! The typed service API: every operation the serving layer supports —
//! data plane (`Infer`), admin plane (`Load`/`LoadSeeded`/`Swap`/
//! `Unload`) and observability plane (`ListModels`/`ModelInfo`/
//! `Stats`) — expressed as one [`Request`]/[`Response`] pair, with a
//! single [`Service::dispatch`] both the in-process callers and the
//! TCP endpoint (`serve::net`) route through. A remote call is
//! therefore the same call: same registry mutation, same
//! [`ModelStamp`] on the response, same refcompute cross-checkability.
//!
//! Errors never escape as `Err`: `dispatch` folds every failure into
//! [`Response::Error`], so the wire protocol needs exactly one
//! response envelope and local callers can match on it the same way a
//! remote client does.
//!
//! [`RegistryManifest`] is the persistence satellite: with
//! `serve --registry-file PATH`, every API-plane registry mutation
//! rewrites a small JSON manifest (name, zoo id, weight seed,
//! version), and a restarted server reloads the exact model set —
//! versions and weights bit-identical, because weights are a pure
//! function of (network, seed).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::ArchConfig;
use crate::model::{zoo, Network};

use super::metrics::ModelMetricsSnapshot;
use super::registry::{ModelRegistry, ModelStamp, ModelVersion};
use super::server::Server;

/// A typed request on the service API. `Infer` is the data plane;
/// `Load`/`LoadSeeded`/`Swap`/`Unload` the admin plane (zoo model
/// names, case-insensitive); `ListModels`/`ModelInfo`/`Stats` the
/// observability plane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    /// Run one image. `model: None` routes to the sole loaded model
    /// (exactly like `Server::submit`); `Some(name)` routes by name.
    Infer { model: Option<String>, image: Vec<i8> },
    /// Compile and publish a zoo model under its canonical name, with
    /// the compiler's deterministic default weight seed.
    Load { model: String },
    /// [`Request::Load`] with an explicit weight seed.
    LoadSeeded { model: String, seed: u64 },
    /// Hot-swap a loaded model to a freshly compiled version;
    /// `seed: Some(_)` makes the swap observable in the outputs.
    Swap { model: String, seed: Option<u64> },
    /// Remove a model; in-flight requests drain on their version.
    Unload { model: String },
    /// Describe every loaded model.
    ListModels,
    /// Describe one loaded model.
    ModelInfo { model: String },
    /// Per-model serving metrics (p50/p95/p99, counts, queue depth).
    Stats,
}

/// The response envelope for every [`Request`]. Failures are
/// [`Response::Error`] — never a transport-level error — so local and
/// remote callers handle them identically.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Response {
    Infer(InferReply),
    Loaded(ModelStamp),
    Swapped(ModelStamp),
    Unloaded(ModelStamp),
    Models(Vec<ModelDesc>),
    Info(ModelDesc),
    Stats(StatsReply),
    Error { message: String },
}

/// A served inference: the logits plus the exact model version that
/// produced them ([`ModelStamp`], for refcompute cross-checks) and the
/// server-side timing split.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InferReply {
    pub logits: Vec<i8>,
    /// `None` only on the single-model PJRT backend.
    pub model: Option<ModelStamp>,
    /// Time the request spent queued (microseconds).
    pub queue_us: u64,
    /// Executor time attributed to the request (microseconds).
    pub exec_us: u64,
}

/// Static description of a model. `id`/`version` are 0 when the model
/// is described from the zoo rather than a live registry entry
/// (`domino models --json`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelDesc {
    pub name: String,
    pub id: u64,
    pub version: u64,
    pub input_len: u64,
    pub classes: u64,
    pub layers: u64,
    pub params: u64,
    pub macs: u64,
}

impl ModelDesc {
    /// Describe a network that is not (necessarily) loaded.
    pub fn of_network(net: &Network) -> Result<Self> {
        Ok(Self {
            name: net.name.clone(),
            id: 0,
            version: 0,
            input_len: net.input_len() as u64,
            classes: net.output_shape()?.c as u64,
            layers: net.layers.len() as u64,
            params: net.total_params()?,
            macs: net.total_macs()?,
        })
    }

    /// Describe a live registry entry.
    pub fn of_version(mv: &ModelVersion) -> Result<Self> {
        let mut d = Self::of_network(&mv.program().net)?;
        d.name = mv.name().to_string();
        d.id = mv.id();
        d.version = mv.version();
        Ok(d)
    }
}

/// The `Stats` payload: the former aggregate counters plus the
/// per-model split ([`ModelMetricsSnapshot`]: served/failed/rejected
/// counts, live queue-depth gauge, p50/p95/p99 latency).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StatsReply {
    pub served: u64,
    pub rejected: u64,
    pub failed: u64,
    pub models: Vec<ModelMetricsSnapshot>,
}

/// One persisted registry entry: enough to recompile the exact same
/// model version after a restart.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Canonical zoo name to recompile from.
    pub zoo: String,
    /// Weight seed (`None` = the compiler's deterministic default).
    pub seed: Option<u64>,
    /// Version to republish at (preserved across restarts).
    pub version: u64,
}

/// The on-disk registry manifest behind `serve --registry-file PATH`:
/// a JSON document (written with the `serve::wire` encoder) rewritten
/// atomically on every API-plane registry mutation and replayed into a
/// fresh [`ModelRegistry`] on restart.
pub struct RegistryManifest {
    path: PathBuf,
    entries: Mutex<BTreeMap<String, ManifestEntry>>,
}

impl RegistryManifest {
    /// Open (and parse) the manifest at `path`; a missing file is an
    /// empty manifest, a malformed one is an error.
    pub fn open(path: &Path) -> Result<Self> {
        let entries = if path.exists() {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("read registry manifest {}", path.display()))?;
            Self::parse(&text)
                .with_context(|| format!("parse registry manifest {}", path.display()))?
        } else {
            BTreeMap::new()
        };
        Ok(Self {
            path: path.to_path_buf(),
            entries: Mutex::new(entries),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.lock().unwrap().is_empty()
    }

    fn parse(text: &str) -> Result<BTreeMap<String, ManifestEntry>> {
        use super::wire::{self, Json};
        let doc = wire::decode(text)?;
        let models = doc
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest has no \"models\" array"))?;
        let mut entries = BTreeMap::new();
        for m in models {
            let name = wire::str_field(m, "name")?;
            let entry = ManifestEntry {
                zoo: wire::str_field(m, "zoo")?,
                seed: wire::opt_u64_field(m, "seed")?,
                version: wire::u64_field(m, "version")?,
            };
            entries.insert(name, entry);
        }
        Ok(entries)
    }

    fn entries_to_json(entries: &BTreeMap<String, ManifestEntry>) -> super::wire::Json {
        use super::wire::Json;
        let models: Vec<Json> = entries
            .iter()
            .map(|(name, e)| {
                Json::Obj(vec![
                    ("name".to_string(), Json::Str(name.clone())),
                    ("zoo".to_string(), Json::Str(e.zoo.clone())),
                    (
                        "seed".to_string(),
                        match e.seed {
                            Some(s) => Json::Int(s as i128),
                            None => Json::Null,
                        },
                    ),
                    ("version".to_string(), Json::Int(e.version as i128)),
                ])
            })
            .collect();
        Json::Obj(vec![("models".to_string(), Json::Arr(models))])
    }

    /// Record (or update) one entry in memory; call [`Self::save`] to
    /// persist.
    pub fn record(&self, name: &str, zoo: &str, seed: Option<u64>, version: u64) {
        self.entries.lock().unwrap().insert(
            name.to_string(),
            ManifestEntry {
                zoo: zoo.to_string(),
                seed,
                version,
            },
        );
    }

    /// Drop one entry in memory; call [`Self::save`] to persist.
    pub fn remove(&self, name: &str) {
        self.entries.lock().unwrap().remove(name);
    }

    /// Atomically rewrite the manifest file (write temp + rename, so a
    /// crash mid-write never leaves a truncated manifest). The entries
    /// lock is held across encode + write + rename: concurrent admin
    /// dispatches share one temp file, and unsynchronized writers
    /// could interleave bytes and publish a mangled manifest.
    pub fn save(&self) -> Result<()> {
        let entries = self.entries.lock().unwrap();
        let text = super::wire::encode(&Self::entries_to_json(&entries));
        let tmp = self.path.with_extension("tmp");
        std::fs::write(&tmp, text.as_bytes())
            .with_context(|| format!("write registry manifest {}", tmp.display()))?;
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("publish registry manifest {}", self.path.display()))?;
        Ok(())
    }

    /// Replay every entry into `registry` (recompiling each model from
    /// its recorded zoo name and seed at its recorded version). Names
    /// already loaded are left untouched. Returns how many models were
    /// restored.
    pub fn restore(&self, registry: &ModelRegistry, arch: ArchConfig) -> Result<usize> {
        let entries = self.entries.lock().unwrap().clone();
        let mut restored = 0;
        for (name, e) in &entries {
            if registry.get(name).is_some() {
                continue;
            }
            let net = zoo::lookup(&e.zoo)
                .with_context(|| format!("restore manifest entry {name:?}"))?;
            registry
                .load_restored(name, &net, arch, e.seed, e.version)
                .with_context(|| format!("restore manifest entry {name:?}"))?;
            restored += 1;
        }
        Ok(restored)
    }
}

/// The one front door for every plane: wraps a running [`Server`] and
/// dispatches typed [`Request`]s, locally or (through `serve::net`)
/// over TCP. Admin mutations optionally persist through a
/// [`RegistryManifest`].
pub struct Service {
    server: Server,
    arch: ArchConfig,
    manifest: Option<Arc<RegistryManifest>>,
}

impl Service {
    pub fn new(server: Server, arch: ArchConfig) -> Self {
        Self {
            server,
            arch,
            manifest: None,
        }
    }

    /// [`Self::new`], persisting every API-plane registry mutation to
    /// `manifest` (see [`RegistryManifest`]).
    pub fn with_manifest(server: Server, arch: ArchConfig, manifest: Arc<RegistryManifest>) -> Self {
        Self {
            server,
            arch,
            manifest: Some(manifest),
        }
    }

    /// The wrapped server (counters, registry, direct submit paths).
    pub fn server(&self) -> &Server {
        &self.server
    }

    /// Shut the wrapped server down (graceful drain; see
    /// `Server::shutdown`).
    pub fn shutdown(self) -> Result<Vec<u64>> {
        self.server.shutdown()
    }

    /// Execute one typed request. This is the single entry point both
    /// the in-process path and the TCP endpoint use; failures become
    /// [`Response::Error`], never `Err`.
    pub fn dispatch(&self, req: Request) -> Response {
        let r = match req {
            Request::Infer { model, image } => self.do_infer(model, image),
            Request::Load { model } => self.do_load(&model, None),
            Request::LoadSeeded { model, seed } => self.do_load(&model, Some(seed)),
            Request::Swap { model, seed } => self.do_swap(&model, seed),
            Request::Unload { model } => self.do_unload(&model),
            Request::ListModels => self.do_list(),
            Request::ModelInfo { model } => self.do_info(&model),
            Request::Stats => Ok(self.do_stats()),
        };
        r.unwrap_or_else(|e| Response::Error {
            message: format!("{e:#}"),
        })
    }

    fn registry(&self) -> Result<&Arc<ModelRegistry>> {
        self.server.registry().ok_or_else(|| {
            anyhow!(
                "the {} backend has no model registry (admin and model \
                 requests need the sim backend)",
                self.server.backend()
            )
        })
    }

    /// Resolve a user-supplied model name to the registry key it is
    /// published under. An exact registry match wins (a prebuilt model
    /// may be published under a name that happens to alias a zoo
    /// entry); otherwise zoo names are canonicalized (`TINY_CNN` →
    /// `tiny-cnn`), and unknown names pass through so registry errors
    /// can list what *is* loaded. Borrowed (allocation-free) in the
    /// common already-canonical case; the registry probe here plus
    /// the lookup inside the eventual registry operation is two cheap
    /// uncontended read-lock hits — noise next to a cycle-accurate
    /// image simulation.
    fn registry_key<'a>(&self, model: &'a str) -> std::borrow::Cow<'a, str> {
        use std::borrow::Cow;
        if let Some(reg) = self.server.registry() {
            if reg.get(model).is_some() {
                return Cow::Borrowed(model);
            }
        }
        match zoo::by_name(model) {
            Some(net) => Cow::Owned(net.name),
            None => Cow::Borrowed(model),
        }
    }

    fn persist(&self) -> Result<()> {
        match &self.manifest {
            Some(m) => m
                .save()
                .context("registry mutation applied, but the manifest write failed"),
            None => Ok(()),
        }
    }

    fn do_infer(&self, model: Option<String>, image: Vec<i8>) -> Result<Response> {
        let r = match &model {
            // canonicalize like every other plane, so the name that
            // worked for Load/ModelInfo also works for Infer
            Some(m) => self.server.infer_on(&self.registry_key(m), image)?,
            None => self.server.infer(image)?,
        };
        Ok(Response::Infer(InferReply {
            logits: r.logits,
            model: r.model,
            queue_us: r.queue.as_micros() as u64,
            exec_us: r.exec.as_micros() as u64,
        }))
    }

    fn do_load(&self, model: &str, seed: Option<u64>) -> Result<Response> {
        let reg = self.registry()?;
        let net = zoo::lookup(model)?;
        let mv = reg.load_seeded(&net.name, &net, self.arch, seed)?;
        if let Some(man) = &self.manifest {
            man.record(&net.name, &net.name, seed, mv.version());
        }
        self.persist()?;
        Ok(Response::Loaded(mv.stamp()))
    }

    fn do_swap(&self, model: &str, seed: Option<u64>) -> Result<Response> {
        let reg = self.registry()?;
        let net = zoo::lookup(model)?;
        let mv = reg.swap_seeded(&net.name, &net, self.arch, seed)?;
        if let Some(man) = &self.manifest {
            man.record(&net.name, &net.name, seed, mv.version());
        }
        self.persist()?;
        Ok(Response::Swapped(mv.stamp()))
    }

    fn do_unload(&self, model: &str) -> Result<Response> {
        let reg = self.registry()?;
        let key = self.registry_key(model);
        let mv = reg.unload(&key)?;
        if let Some(man) = &self.manifest {
            man.remove(&key);
        }
        self.persist()?;
        Ok(Response::Unloaded(mv.stamp()))
    }

    fn do_list(&self) -> Result<Response> {
        let reg = self.registry()?;
        let descs: Vec<ModelDesc> = reg
            .list()
            .iter()
            .map(|mv| ModelDesc::of_version(mv))
            .collect::<Result<_>>()?;
        Ok(Response::Models(descs))
    }

    fn do_info(&self, model: &str) -> Result<Response> {
        let reg = self.registry()?;
        let key = self.registry_key(model);
        let mv = reg.get(&key).ok_or_else(|| {
            anyhow!(
                "model {model:?} is not loaded (loaded: [{}])",
                reg.names().join(", ")
            )
        })?;
        Ok(Response::Info(ModelDesc::of_version(&mv)?))
    }

    fn do_stats(&self) -> Response {
        Response::Stats(StatsReply {
            served: self.server.served(),
            rejected: self.server.rejected(),
            failed: self.server.failed(),
            models: self.server.metrics_snapshot(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::ServeConfig;

    fn start_service() -> Service {
        let registry = Arc::new(ModelRegistry::new());
        let net = zoo::tiny_mlp();
        registry.load(&net.name, &net, ArchConfig::default()).unwrap();
        let server = Server::start_multi(
            ServeConfig {
                workers: 1,
                max_batch: 4,
                queue_cap: 64,
            },
            registry,
        )
        .unwrap();
        Service::new(server, ArchConfig::default())
    }

    #[test]
    fn dispatch_covers_all_three_planes_and_matches_inprocess() {
        let service = start_service();

        // admin plane: load a second model by (case-insensitive) name
        let stamp = match service.dispatch(Request::LoadSeeded {
            model: "TINY_RESNET".into(),
            seed: 0xAB,
        }) {
            Response::Loaded(s) => s,
            other => panic!("expected Loaded, got {other:?}"),
        };
        assert_eq!(&*stamp.name, "tiny-resnet");
        assert_eq!(stamp.version, 1);

        // observability plane: both models described
        let models = match service.dispatch(Request::ListModels) {
            Response::Models(m) => m,
            other => panic!("expected Models, got {other:?}"),
        };
        let names: Vec<&str> = models.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(names, ["tiny-mlp", "tiny-resnet"]);
        assert!(models.iter().all(|d| d.params > 0 && d.macs > 0));

        // data plane: dispatch(Infer) is the same call as infer_on —
        // same model version stamp, same logits
        let mv = service
            .server()
            .registry()
            .unwrap()
            .get("tiny-resnet")
            .unwrap();
        let image = vec![5i8; mv.input_len()];
        let reply = match service.dispatch(Request::Infer {
            model: Some("tiny-resnet".into()),
            image: image.clone(),
        }) {
            Response::Infer(r) => r,
            other => panic!("expected Infer, got {other:?}"),
        };
        let direct = service.server().infer_on("tiny-resnet", image.clone()).unwrap();
        assert_eq!(reply.logits, direct.logits);
        assert_eq!(reply.model.as_ref(), direct.model.as_ref());
        assert_eq!(reply.logits, mv.refcompute(&image).unwrap());

        // swap bumps the stamp; infer after swap runs the new version
        let swapped = match service.dispatch(Request::Swap {
            model: "tiny-resnet".into(),
            seed: Some(0xCD),
        }) {
            Response::Swapped(s) => s,
            other => panic!("expected Swapped, got {other:?}"),
        };
        assert_eq!(swapped.version, 2);
        let reply2 = match service.dispatch(Request::Infer {
            model: Some("tiny-resnet".into()),
            image: image.clone(),
        }) {
            Response::Infer(r) => r,
            other => panic!("expected Infer, got {other:?}"),
        };
        assert_eq!(reply2.model.as_ref().unwrap().version, 2);
        let mv2 = service
            .server()
            .registry()
            .unwrap()
            .get("tiny-resnet")
            .unwrap();
        assert_eq!(reply2.logits, mv2.refcompute(&image).unwrap());

        // stats plane: per-model entries with counts and percentiles
        let stats = match service.dispatch(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("expected Stats, got {other:?}"),
        };
        assert_eq!(stats.served, 3);
        let resnet = stats
            .models
            .iter()
            .find(|m| m.model == "tiny-resnet")
            .expect("per-model stats entry");
        assert_eq!(resnet.served, 3, "all three infers targeted tiny-resnet");
        assert!(resnet.p50_us.is_some());

        // unload, then errors are typed — never panics or Err
        match service.dispatch(Request::Unload {
            model: "tiny-resnet".into(),
        }) {
            Response::Unloaded(s) => assert_eq!(&*s.name, "tiny-resnet"),
            other => panic!("expected Unloaded, got {other:?}"),
        }
        match service.dispatch(Request::Infer {
            model: Some("tiny-resnet".into()),
            image,
        }) {
            Response::Error { message } => {
                assert!(message.contains("tiny-mlp"), "{message}")
            }
            other => panic!("expected Error, got {other:?}"),
        }
        match service.dispatch(Request::ModelInfo {
            model: "nope".into(),
        }) {
            Response::Error { message } => {
                assert!(message.contains("not loaded") || message.contains("unknown"), "{message}")
            }
            other => panic!("expected Error, got {other:?}"),
        }

        service.shutdown().unwrap();
    }

    #[test]
    fn manifest_roundtrips_and_restores_exact_versions() {
        let path = std::env::temp_dir().join(format!(
            "domino-manifest-test-{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);

        // first life: load + swap through the manifest
        let man = RegistryManifest::open(&path).unwrap();
        assert!(man.is_empty());
        man.record("tiny-mlp", "tiny-mlp", Some(0xAA), 1);
        man.record("tiny-resnet", "tiny-resnet", None, 3);
        man.save().unwrap();
        assert!(path.exists());

        // second life: reopen and replay into a fresh registry
        let man2 = RegistryManifest::open(&path).unwrap();
        assert_eq!(man2.len(), 2);
        let registry = ModelRegistry::new();
        let restored = man2.restore(&registry, ArchConfig::default()).unwrap();
        assert_eq!(restored, 2);
        let mlp = registry.get("tiny-mlp").unwrap();
        assert_eq!(mlp.version(), 1);
        let resnet = registry.get("tiny-resnet").unwrap();
        assert_eq!(resnet.version(), 3, "version survives the restart");

        // the restored weights are the same pure function of the seed
        let direct = ModelRegistry::new();
        let want = direct
            .load_seeded("tiny-mlp", &zoo::tiny_mlp(), ArchConfig::default(), Some(0xAA))
            .unwrap();
        let img = vec![7i8; mlp.input_len()];
        assert_eq!(mlp.refcompute(&img).unwrap(), want.refcompute(&img).unwrap());

        // restore skips names that are already loaded
        assert_eq!(man2.restore(&registry, ArchConfig::default()).unwrap(), 0);

        let _ = std::fs::remove_file(&path);
    }
}
