//! The bounded-queue / micro-batch server core: worker pool, the two
//! execution backends (PJRT, cycle simulator over a [`ModelRegistry`])
//! and the graceful-shutdown drain semantics. See the `serve` module
//! docs for the full contract.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::Program;
use crate::sim::EnginePool;

use super::metrics::{MetricsHub, ModelMetricsSnapshot, UNTAGGED_MODEL};
use super::registry::{ModelRegistry, ModelStamp, ModelVersion};

/// One inference request.
pub struct Request {
    pub id: u64,
    pub image: Vec<i8>,
    /// Model version resolved at submit time (`None` on the PJRT
    /// path). A swap or unload after submission does not affect this
    /// request: it executes on exactly this version (drain semantics).
    model: Option<Arc<ModelVersion>>,
    enqueued: Instant,
    resp: mpsc::Sender<Response>,
}

/// The per-model metrics key for a queued request.
fn metric_name(req: &Request) -> &str {
    req.model.as_ref().map(|m| m.name()).unwrap_or(UNTAGGED_MODEL)
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<i8>,
    /// Exactly which model version served this request (`None` on the
    /// PJRT path). Cross-check `logits` against this version's weights.
    pub model: Option<ModelStamp>,
    /// Time spent queued before a worker picked the request up.
    pub queue: Duration,
    /// Executor time (batch time attributed per request).
    pub exec: Duration,
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker threads (each with a private execution engine pool).
    pub workers: usize,
    /// Max requests drained per dequeue (micro-batch).
    pub max_batch: usize,
    /// Queue capacity; `submit` fails fast beyond it (backpressure).
    pub queue_cap: usize,
    /// Dispatcher threads of the TCP endpoint fronting this server
    /// (plumbed into [`crate::serve::net::NetConfig::dispatchers`] by
    /// the `serve`/`cluster serve` entry points; unused by in-process
    /// servers). Zero is rejected at bind time with
    /// [`crate::serve::net::ZeroDispatchers`].
    pub dispatchers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 8,
            queue_cap: 256,
            dispatchers: 4,
        }
    }
}

#[derive(Default)]
struct Shared {
    queue: Mutex<VecDeque<Request>>,
    cv: Condvar,
    stop: AtomicBool,
    served: AtomicU64,
    rejected: AtomicU64,
    /// Requests whose execution failed (the client's channel is closed
    /// instead of answered; workers keep serving).
    failed: AtomicU64,
    /// Per-model counters, gauges and latency windows.
    metrics: MetricsHub,
}

/// Which execution engine the workers build (internal; selected by the
/// `Server` constructor used).
enum BackendSpec {
    /// AOT artifact through a per-worker PJRT client.
    Pjrt,
    /// Cycle-accurate engines over a shared model registry; requests
    /// are routed by the model version they carry.
    Sim(Arc<ModelRegistry>),
}

/// What a worker thread runs per request. `batch_done` fires after each
/// drained micro-batch (engine-cache pruning and similar bookkeeping).
trait Backend {
    fn infer(&mut self, req: &Request) -> Result<Vec<i8>>;
    fn batch_done(&mut self) {}
}

/// PJRT worker state: one full client per worker (handles aren't Send).
struct PjrtBackend {
    exe: crate::runtime::golden::TrainedTiny,
}

impl Backend for PjrtBackend {
    fn infer(&mut self, req: &Request) -> Result<Vec<i8>> {
        self.exe.run(&req.image)
    }
}

/// Simulator worker state: one warm engine per loaded model, keyed by
/// model-version id.
struct SimBackend {
    registry: Arc<ModelRegistry>,
    pool: EnginePool,
    /// Registry generation last reconciled against; pruning runs only
    /// when it moves, keeping the steady-state serving path free of
    /// registry locks and allocations.
    seen_generation: u64,
}

impl Backend for SimBackend {
    fn infer(&mut self, req: &Request) -> Result<Vec<i8>> {
        let mv = req
            .model
            .as_ref()
            .ok_or_else(|| anyhow!("sim request without a model tag"))?;
        let out = self.pool.engine(mv.id(), mv.program()).run_image(&req.image)?;
        Ok(out.scores)
    }

    fn batch_done(&mut self) {
        // Drop engines of swapped-away / unloaded versions so a dead
        // version's compiled program is released promptly (a
        // length-based check would miss a swap, which replaces a key
        // without changing the count and would pin the old program for
        // the process lifetime). Gated on the registry's mutation
        // generation so unchanged registries cost nothing here. A
        // still-queued request that holds a pruned version simply
        // rebuilds its engine on demand.
        let generation = self.registry.generation();
        if generation != self.seen_generation {
            self.seen_generation = generation;
            self.pool.retain_keys(&self.registry.live_ids());
        }
    }
}

/// A running inference server.
pub struct Server {
    shared: Arc<Shared>,
    cfg: ServeConfig,
    workers: Vec<std::thread::JoinHandle<Result<u64>>>,
    next_id: AtomicU64,
    input_len: usize,
    backend: &'static str,
    registry: Option<Arc<ModelRegistry>>,
}

impl Server {
    /// Start `cfg.workers` threads serving the trained tiny-cnn
    /// artifact over PJRT. Fails immediately if the artifacts are
    /// missing.
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        if !crate::runtime::artifacts_available() {
            bail!("artifacts not built (run `make artifacts`)");
        }
        Self::start_backend(cfg, BackendSpec::Pjrt, 3 * 16 * 16, "pjrt")
    }

    /// Start `cfg.workers` threads serving the cycle-accurate simulator
    /// over one shared compiled program (see [`super::sim_program`]).
    /// Needs no artifacts: the whole datapath is the Rust engine.
    /// Internally this is a single-entry [`ModelRegistry`] (named after
    /// the network), so [`Self::submit`] routes without a model tag.
    pub fn start_sim(cfg: ServeConfig, program: Arc<Program>) -> Result<Self> {
        let input_len = program.net.input_len();
        let registry = Arc::new(ModelRegistry::new());
        let name = program.net.name.clone();
        registry.load_prebuilt(&name, program, None)?;
        Self::start_backend(cfg, BackendSpec::Sim(registry), input_len, "sim")
    }

    /// Start `cfg.workers` threads serving every model in `registry`,
    /// with requests routed by model name ([`Self::submit_to`]) and
    /// hot-swap/load/unload available through the registry while
    /// serving. Each worker pre-builds one engine per model loaded at
    /// startup; models loaded later get engines lazily on first
    /// request.
    pub fn start_multi(cfg: ServeConfig, registry: Arc<ModelRegistry>) -> Result<Self> {
        anyhow::ensure!(
            !registry.is_empty(),
            "model registry has no models loaded"
        );
        let input_len = registry.sole().map(|m| m.input_len()).unwrap_or(0);
        Self::start_backend(cfg, BackendSpec::Sim(registry), input_len, "sim")
    }

    fn start_backend(
        cfg: ServeConfig,
        spec: BackendSpec,
        input_len: usize,
        backend: &'static str,
    ) -> Result<Self> {
        anyhow::ensure!(cfg.workers >= 1 && cfg.max_batch >= 1);
        let registry = match &spec {
            BackendSpec::Sim(r) => Some(Arc::clone(r)),
            BackendSpec::Pjrt => None,
        };
        let shared = Arc::new(Shared::default());
        let mut workers = Vec::with_capacity(cfg.workers);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let ready = ready_tx.clone();
            let max_batch = cfg.max_batch;
            let spec = match &spec {
                BackendSpec::Pjrt => BackendSpec::Pjrt,
                BackendSpec::Sim(r) => BackendSpec::Sim(Arc::clone(r)),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("domino-worker-{w}"))
                    .spawn(move || worker_entry(shared, max_batch, spec, ready))
                    .context("spawn worker")?,
            );
        }
        drop(ready_tx);
        // wait until every worker has built its execution engine(s)
        for _ in 0..cfg.workers {
            ready_rx
                .recv()
                .context("worker died during startup")??;
        }
        Ok(Self {
            shared,
            cfg,
            workers,
            next_id: AtomicU64::new(0),
            input_len,
            backend,
            registry,
        })
    }

    /// Flat input length this server accepts through [`Self::submit`]:
    /// the sole loaded model's input on the sim backend (tracking the
    /// live registry, so 0 once several models are loaded — use
    /// [`ModelVersion::input_len`] per model then), or the fixed
    /// artifact input on PJRT.
    pub fn input_len(&self) -> usize {
        match &self.registry {
            None => self.input_len,
            Some(reg) => reg.sole().map(|m| m.input_len()).unwrap_or(0),
        }
    }

    /// Which backend the workers run (`"pjrt"` or `"sim"`).
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// The model registry behind a sim server (`None` on PJRT). Use it
    /// to load/swap/unload models while serving.
    pub fn registry(&self) -> Option<&Arc<ModelRegistry>> {
        self.registry.as_ref()
    }

    /// Submit one image to the server's sole model; returns a receiver
    /// for the response. Fails fast when the queue is full
    /// (backpressure), the image is the wrong size, or more than one
    /// model is loaded (use [`Self::submit_to`] then).
    pub fn submit(&self, image: Vec<i8>) -> Result<mpsc::Receiver<Response>> {
        match &self.registry {
            None => self.enqueue(None, image),
            Some(reg) => {
                let mv = reg.sole().ok_or_else(|| {
                    anyhow!(
                        "{} models loaded ([{}]); name one with submit_to",
                        reg.len(),
                        reg.names().join(", ")
                    )
                })?;
                self.enqueue(Some(mv), image)
            }
        }
    }

    /// Submit one image to the named model. The model version is
    /// resolved now and travels with the request: a swap or unload
    /// between submit and execution does not affect it.
    pub fn submit_to(&self, model: &str, image: Vec<i8>) -> Result<mpsc::Receiver<Response>> {
        let reg = self.registry.as_ref().ok_or_else(|| {
            anyhow!(
                "the {} backend is single-model; use submit",
                self.backend
            )
        })?;
        let mv = reg.get(model).ok_or_else(|| {
            anyhow!(
                "model {model:?} is not loaded (loaded: [{}])",
                reg.names().join(", ")
            )
        })?;
        self.enqueue(Some(mv), image)
    }

    fn enqueue(
        &self,
        model: Option<Arc<ModelVersion>>,
        image: Vec<i8>,
    ) -> Result<mpsc::Receiver<Response>> {
        let want = model
            .as_ref()
            .map(|m| m.input_len())
            .unwrap_or(self.input_len);
        if image.len() != want {
            match &model {
                Some(m) => bail!(
                    "image for model {:?} must be {want} int8 values (got {})",
                    m.name(),
                    image.len()
                ),
                None => bail!("image must be {want} int8 values (got {})", image.len()),
            }
        }
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.cfg.queue_cap {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                self.shared
                    .metrics
                    .on_reject(model.as_ref().map(|m| m.name()).unwrap_or(UNTAGGED_MODEL));
                bail!("queue full ({}): backpressure", self.cfg.queue_cap);
            }
            // Gauge up while holding the queue lock, *before* the
            // request becomes visible to workers: a worker cannot
            // have dequeued it yet, so the depth gauge can never
            // transiently go negative (and saturate into a permanent
            // off-by-one). Borrowing the name here (instead of
            // allocating a String) is why this runs before `model`
            // moves into the queue entry.
            self.shared
                .metrics
                .on_enqueue(model.as_ref().map(|m| m.name()).unwrap_or(UNTAGGED_MODEL));
            q.push_back(Request {
                id,
                image,
                model,
                enqueued: Instant::now(),
                resp: tx,
            });
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Synchronous convenience: submit + wait.
    pub fn infer(&self, image: Vec<i8>) -> Result<Response> {
        let rx = self.submit(image)?;
        rx.recv().context("worker dropped the request")
    }

    /// Synchronous convenience: submit to a named model + wait.
    pub fn infer_on(&self, model: &str, image: Vec<i8>) -> Result<Response> {
        let rx = self.submit_to(model, image)?;
        rx.recv().context("worker dropped the request")
    }

    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Requests whose execution failed after being accepted. Each one
    /// had its response channel closed (the client's `recv` errors)
    /// rather than hanging; the worker that hit the failure keeps
    /// serving.
    pub fn failed(&self) -> u64 {
        self.shared.failed.load(Ordering::Relaxed)
    }

    /// Per-model counters, queue-depth gauges and latency percentiles
    /// (the aggregate counters above stay available for cheap checks).
    pub fn metrics_snapshot(&self) -> Vec<ModelMetricsSnapshot> {
        self.shared.metrics.snapshot()
    }

    /// Count a served flight-recorder trace for `model`
    /// (`serve::api`'s `Request::Trace` plane).
    pub(crate) fn note_trace(&self, model: &str) {
        self.shared.metrics.on_trace(model);
    }

    /// Count an inference served inline by the fault plane (`serve::
    /// api` runs armed models through a fault-injecting engine on the
    /// dispatching thread, bypassing the queue so corruption is
    /// deterministic per request). To the client this is ordinary data
    /// plane traffic, so it lands in the same served counters and
    /// latency windows.
    pub(crate) fn note_fault_serve(&self, model: &str, latency: Duration) {
        self.shared.served.fetch_add(1, Ordering::Relaxed);
        self.shared.metrics.on_served(model, latency);
    }

    /// Set or clear the fault plane's degraded flag for `model` in the
    /// per-model metrics (surfaced by `Stats`).
    pub(crate) fn set_degraded(&self, model: &str, degraded: bool) {
        self.shared.metrics.set_degraded(model, degraded);
    }

    /// Stop workers and join them; returns per-worker served counts.
    ///
    /// Workers drain the queue before exiting, so every request
    /// accepted by `submit` before this call is still resolved —
    /// answered, or its channel closed if its execution failed. This
    /// holds with any number of models loaded, including versions
    /// unloaded or swapped away while their requests were queued.
    pub fn shutdown(mut self) -> Result<Vec<u64>> {
        {
            // Publish `stop` while holding the queue mutex: a worker is
            // either before its predicate check (it will see the flag)
            // or already parked in `wait` (it will see the notify).
            // Storing without the lock could slot between a worker's
            // check and its wait, losing the wakeup forever.
            let _q = self.shared.queue.lock().unwrap();
            self.shared.stop.store(true, Ordering::SeqCst);
        }
        self.shared.cv.notify_all();
        let mut counts = Vec::new();
        for w in self.workers.drain(..) {
            counts.push(w.join().map_err(|_| anyhow::anyhow!("worker panicked"))??);
        }
        Ok(counts)
    }
}

/// Worker thread entry: build the backend's execution engine(s), signal
/// readiness, then serve micro-batches until shutdown.
fn worker_entry(
    shared: Arc<Shared>,
    max_batch: usize,
    spec: BackendSpec,
    ready: mpsc::Sender<Result<()>>,
) -> Result<u64> {
    match spec {
        BackendSpec::Pjrt => {
            // each worker owns a full PJRT stack (handles are not Send)
            let init = (|| -> Result<crate::runtime::golden::TrainedTiny> {
                let rt = crate::runtime::Runtime::cpu()?;
                crate::runtime::golden::TrainedTiny::load(&rt)
            })();
            let exe = match init {
                Ok(e) => {
                    let _ = ready.send(Ok(()));
                    e
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    let _ = ready.send(Err(e));
                    bail!("worker init failed: {msg}");
                }
            };
            Ok(serve_loop(&shared, max_batch, PjrtBackend { exe }))
        }
        BackendSpec::Sim(registry) => {
            // Warm the per-worker engine cache for every model loaded
            // at startup, so `ready` means "engines built" (models
            // loaded later build lazily on their first request). The
            // generation is sampled *before* warming: a registry
            // mutation racing the warm-up is then caught by the first
            // batch_done prune.
            let seen_generation = registry.generation();
            let mut pool = EnginePool::new();
            for mv in registry.list() {
                pool.engine(mv.id(), mv.program());
            }
            let _ = ready.send(Ok(()));
            Ok(serve_loop(
                &shared,
                max_batch,
                SimBackend {
                    registry,
                    pool,
                    seen_generation,
                },
            ))
        }
    }
}

/// The backend-agnostic micro-batch loop: block until work or stop,
/// drain up to `max_batch` requests, execute, respond. Returns the
/// number of requests this worker served.
///
/// A per-request execution failure never kills the worker: the failed
/// request's response channel is dropped (so the client's `recv`
/// errors instead of hanging), the failure is counted, and serving
/// continues — otherwise one poisoned request could strand every
/// request still in the queue.
fn serve_loop<B: Backend>(shared: &Shared, max_batch: usize, mut backend: B) -> u64 {
    let mut served = 0u64;
    let mut batch: Vec<Request> = Vec::with_capacity(max_batch);
    loop {
        batch.clear();
        {
            let mut q = shared.queue.lock().unwrap();
            // `stop` is re-checked on every wakeup; because `shutdown`
            // publishes it under this mutex, the check-then-wait pair
            // cannot miss it.
            while q.is_empty() && !shared.stop.load(Ordering::SeqCst) {
                q = shared.cv.wait(q).unwrap();
            }
            if q.is_empty() && shared.stop.load(Ordering::SeqCst) {
                return served;
            }
            for _ in 0..max_batch {
                match q.pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
        }
        for req in &batch {
            shared.metrics.on_dequeue(metric_name(req));
        }
        let t0 = Instant::now();
        let n = batch.len() as u32;
        for req in batch.drain(..) {
            let queue = req.enqueued.elapsed();
            match backend.infer(&req) {
                Ok(logits) => {
                    let exec = t0.elapsed() / n;
                    shared.served.fetch_add(1, Ordering::Relaxed);
                    shared.metrics.on_served(metric_name(&req), queue + exec);
                    served += 1;
                    // client may have gone away; that's fine
                    let _ = req.resp.send(Response {
                        id: req.id,
                        logits,
                        model: req.model.as_ref().map(|m| m.stamp()),
                        queue,
                        exec,
                    });
                }
                Err(e) => {
                    shared.failed.fetch_add(1, Ordering::Relaxed);
                    shared.metrics.on_failed(metric_name(&req));
                    eprintln!("domino-serve: request {} failed: {e:#}", req.id);
                    // dropping req.resp closes the channel: the client
                    // unblocks with a recv error instead of hanging
                }
            }
        }
        backend.batch_done();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ArchConfig;
    use crate::model::refcompute::{forward, Tensor};
    use crate::model::{Network, NetworkBuilder, TensorShape};
    use crate::serve::sim_program;
    use crate::testutil::Rng;

    /// A small conv net the sim backend can serve in well under a
    /// millisecond per image.
    fn small_net() -> Network {
        NetworkBuilder::new("serve-test", TensorShape::new(2, 6, 6))
            .conv(4, 3, 1, 1)
            .flatten()
            .fc_logits(5)
            .build()
    }

    #[test]
    fn sim_backend_rejects_zero_workers() {
        let net = small_net();
        let (program, _) = sim_program(&net, ArchConfig::default()).unwrap();
        let bad = ServeConfig {
            workers: 0,
            ..Default::default()
        };
        assert!(Server::start_sim(bad, program).is_err());
    }

    #[test]
    fn sim_backend_roundtrip_matches_refcompute() {
        let net = small_net();
        let (program, weights) = sim_program(&net, ArchConfig::default()).unwrap();
        let server = Server::start_sim(
            ServeConfig {
                workers: 2,
                max_batch: 4,
                queue_cap: 64,
                ..ServeConfig::default()
            },
            Arc::clone(&program),
        )
        .unwrap();
        assert_eq!(server.backend(), "sim");
        assert_eq!(server.input_len(), net.input_len());
        // wrong-size image rejected up front
        assert!(server.submit(vec![0i8; 3]).is_err());
        // responses are bit-exact vs the int8 reference, and stamped
        // with the (sole) model that served them
        let mut rng = Rng::new(77);
        for _ in 0..6 {
            let image = rng.i8_vec(net.input_len(), 31);
            let r = server.infer(image.clone()).unwrap();
            let want = forward(&net, &weights, &Tensor::new(net.input, image)).unwrap();
            assert_eq!(r.logits, want.data);
            let stamp = r.model.expect("sim responses carry a model stamp");
            assert_eq!(&*stamp.name, "serve-test");
            assert_eq!(stamp.version, 1);
        }
        assert_eq!(server.served(), 6);
        // per-model metrics tracked the traffic under the model's name
        let snap = server.metrics_snapshot();
        let m = snap
            .iter()
            .find(|s| s.model == "serve-test")
            .expect("per-model metrics entry");
        assert_eq!(m.served, 6);
        assert_eq!(m.failed, 0);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.queue_depth, 0, "queue drained");
        assert_eq!(m.samples, 6);
        assert!(m.p50_us.is_some() && m.p99_us.is_some());
        assert!(m.p50_us <= m.p99_us);
        let counts = server.shutdown().unwrap();
        assert_eq!(counts.iter().sum::<u64>(), 6);
    }

    #[test]
    fn sim_backend_shutdown_under_load_answers_everything() {
        // Regression test for the missed-wakeup shutdown race: repeat
        // the submit-burst → immediate-shutdown cycle; with the old
        // unsynchronized `stop` store a worker could park forever and
        // `shutdown` would hang (the test would time out).
        let net = small_net();
        let (program, _) = sim_program(&net, ArchConfig::default()).unwrap();
        let mut rng = Rng::new(99);
        for round in 0..6 {
            let server = Server::start_sim(
                ServeConfig {
                    workers: 2,
                    max_batch: 3,
                    queue_cap: 128,
                    ..ServeConfig::default()
                },
                Arc::clone(&program),
            )
            .unwrap();
            let n = 4 + 3 * round as usize;
            let receivers: Vec<_> = (0..n)
                .map(|_| server.submit(rng.i8_vec(net.input_len(), 31)).unwrap())
                .collect();
            // shut down with the queue still loaded: workers must
            // drain it and answer every accepted request
            let counts = server.shutdown().unwrap();
            assert_eq!(counts.iter().sum::<u64>(), n as u64, "round {round}");
            for (i, rx) in receivers.into_iter().enumerate() {
                let r = rx.recv().expect("accepted request must be answered");
                assert_eq!(r.logits.len(), 5, "round {round} request {i}");
            }
        }
    }

    #[test]
    fn submit_requires_model_name_with_multiple_models() {
        let registry = Arc::new(ModelRegistry::new());
        let net = small_net();
        registry.load("a", &net, ArchConfig::default()).unwrap();
        registry.load("b", &net, ArchConfig::default()).unwrap();
        let server = Server::start_multi(
            ServeConfig {
                workers: 1,
                max_batch: 2,
                queue_cap: 16,
                ..ServeConfig::default()
            },
            Arc::clone(&registry),
        )
        .unwrap();
        let img = vec![0i8; net.input_len()];
        let err = server.submit(img.clone()).unwrap_err().to_string();
        assert!(err.contains("submit_to"), "{err}");
        // named routing works for both
        assert_eq!(server.infer_on("a", img.clone()).unwrap().logits.len(), 5);
        assert_eq!(server.infer_on("b", img).unwrap().logits.len(), 5);
        // unknown model error lists the loaded names
        let err = server
            .submit_to("c", vec![0i8; net.input_len()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("[a, b]"), "{err}");
        // metrics split by model name
        let snap = server.metrics_snapshot();
        let names: Vec<&str> = snap.iter().map(|s| s.model.as_str()).collect();
        assert!(names.contains(&"a") && names.contains(&"b"), "{names:?}");
        server.shutdown().unwrap();
    }

    #[test]
    fn start_multi_rejects_empty_registry() {
        let registry = Arc::new(ModelRegistry::new());
        assert!(Server::start_multi(ServeConfig::default(), registry).is_err());
    }

    #[test]
    fn config_validation() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bad = ServeConfig {
            workers: 0,
            ..Default::default()
        };
        assert!(Server::start(bad).is_err());
    }

    #[test]
    fn serve_roundtrip_and_backpressure() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let server = Server::start(ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_cap: 8,
            ..ServeConfig::default()
        })
        .unwrap();
        // wrong-size image rejected up front
        assert!(server.submit(vec![0i8; 3]).is_err());
        // correct request round-trips
        let r = server.infer(vec![1i8; 768]).unwrap();
        assert_eq!(r.logits.len(), 10);
        assert_eq!(server.served(), 1);
        // responses are deterministic
        let r2 = server.infer(vec![1i8; 768]).unwrap();
        assert_eq!(r.logits, r2.logits);
        let counts = server.shutdown().unwrap();
        assert_eq!(counts.iter().sum::<u64>(), 2);
    }
}
