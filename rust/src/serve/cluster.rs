//! The cluster plane: one [`Router`] frontend sharding, replicating
//! and failing over across many `serve` processes, behind the same
//! typed API as a single process.
//!
//! The router owns a table of backend endpoints and implements
//! [`api::Dispatcher`], so everything that can front a leaf `Service`
//! — the TCP endpoint, the benches, the traffic harness — can front a
//! cluster unchanged; a remote call is the same call, one level up.
//!
//! - **Routing.** `Infer` routes by rendezvous (highest-random-weight)
//!   hashing of the model name over the live backends: each model has
//!   a stable owner set of [`ClusterConfig::replication`] backends
//!   that survives unrelated backends joining or dying, and within
//!   the owner set each request goes to the replica with the fewest
//!   router-observed requests in flight (least-loaded dispatch).
//! - **Admin plane.** `Load`/`LoadSeeded`/`Swap` fan out to the
//!   model's owner set and are recorded in the router's model table —
//!   the cluster's manifest — so failover can re-load the model
//!   elsewhere from `(zoo name, seed, mapping)` alone: weights are a
//!   pure function of (network, seed), so a re-load is bit-identical.
//!   `Unload` fans to every live backend and drops the table entry.
//! - **Connection pooling.** Data-plane `Infer` calls multiplex over
//!   a small per-backend pool of pipelined wire-v2 connections
//!   ([`ClusterConfig::pipe_conns`] of them), claimed by request id:
//!   one socket carries many in-flight infers instead of one socket
//!   per request. Admin and observability calls ride plain pooled
//!   synchronous connections. Both pools recycle their sockets on any
//!   transport error and are cleared outright when a backend is
//!   marked dead; [`BackendStatus::dials`] counts fresh routed-call
//!   connections so tests can pin the reuse.
//! - **Observability.** `Stats` aggregates every backend (counters
//!   summed, per-model percentiles folded by max); `ListModels`
//!   unions; `ModelInfo`/`Trace` go to the model's primary owner.
//! - **Health + failover.** A health thread probes every backend over
//!   the existing typed API (`ListModels` doubles as liveness probe
//!   and loaded-set report), marks unresponsive backends dead, and
//!   re-loads owned models onto owners that are missing them. A
//!   transport failure during a call marks the backend dead on the
//!   spot and the infer retries on the next replica, so a kill -9
//!   backend costs retries, not answers. [`Router::drain`] is the
//!   polite version: the backend stops receiving new work, finishes
//!   its in-flight requests, and only then is removed.
//! - **Canary checks.** Dead sockets are the easy failure; a CIM
//!   tile serving silently-wrong bits answers every probe. So the
//!   same health pass also runs a seeded canary inference per owned
//!   model on each backend and compares against the refcompute
//!   oracle (`Request::Canary`): a backend whose canary fails is
//!   excluded from routing exactly like a dead one — same owner-set
//!   re-rank, same repair loop re-loading its models on the
//!   survivors — while `cluster status` reports it as
//!   `canary-failed` rather than `DEAD`, because the operator's fix
//!   is different (re-map or fault-heal, not restart).
//!
//! # Security
//!
//! The wire protocol is **plaintext and unauthenticated** — length-
//! prefixed JSON with no TLS and no credentials. That was a footnote
//! while everything lived on one localhost; the cluster plane is the
//! component that puts frames on a real network, so it inherits the
//! warning at full strength: run routers and backends on a trusted
//! network (localhost, a private segment, or inside a mesh that adds
//! transport security), never on an address the internet can reach.
//! The admin plane (`Load`/`Swap`/`Unload`) is reachable by anyone
//! who can open a TCP connection.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::model::zoo;

use super::api::{self, Dispatcher, MappingSpec, Request, Response, StatsReply};
use super::client::Client;

/// Router tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// How many backends own (and serve) each model. Clamped to the
    /// number of live backends.
    pub replication: usize,
    /// Health-probe cadence.
    pub health_interval: Duration,
    /// Read timeout for routed data/admin calls.
    pub request_timeout: Duration,
    /// Read timeout for health probes (shorter: a probe that hangs
    /// this long *is* the failure signal).
    pub health_timeout: Duration,
    /// Run a seeded canary inference per owned model during each
    /// health pass, excluding backends that serve silently-wrong
    /// outputs from routing (see the module docs).
    pub canary: bool,
    /// Dial attempts when opening a fresh routed connection
    /// (exponential backoff with deterministic jitter between them;
    /// see [`Client::connect_with_backoff`]).
    pub connect_attempts: u32,
    /// Base delay of that backoff schedule.
    pub connect_backoff: Duration,
    /// Pipelined wire-v2 connections kept per backend for `Infer`
    /// dispatch: the data-plane pool `Infer` requests multiplex over
    /// by request id (see [`Client::submit`]). Clamped to >= 1.
    pub pipe_conns: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            replication: 2,
            health_interval: Duration::from_millis(500),
            request_timeout: Duration::from_secs(30),
            health_timeout: Duration::from_secs(2),
            canary: true,
            connect_attempts: 3,
            connect_backoff: Duration::from_millis(10),
            pipe_conns: 2,
        }
    }
}

/// The image seed canary probes use. Fixed and shared across every
/// probe: the canary must be deterministic (same image, same oracle)
/// so a failure is a property of the backend, never of the draw.
pub const CANARY_SEED: u64 = 0xCA_11_A2;

/// What the router remembers about a model it loaded: enough to
/// re-load it, bit-identically, on another backend during failover.
#[derive(Clone, Debug, Default)]
struct ModelSpec {
    seed: Option<u64>,
    mapping: Option<MappingSpec>,
}

/// One slot of a backend's pipelined data-plane pool: a wire-v2
/// connection many `Infer` requests share by request id.
///
/// The concurrency protocol is leader/follower. Submitting is quick
/// (one framed write under the slot lock). Awaiting elects one
/// *reader* per slot: it checks the client out of the slot and drives
/// the socket with [`Client::await_response`] — which parks other
/// ids' responses inside the client — while every other waiter sleeps
/// on the condvar and, on each wake, polls [`Client::take_ready`] for
/// its own id. Requests submitted while a reader is out queue on the
/// condvar, so the lock is never held across a blocking read.
struct PipeSlot {
    state: Mutex<PipeState>,
    cv: Condvar,
}

struct PipeState {
    /// The pooled connection: `None` before the first dial, after a
    /// recycle, or while the reader has it checked out (the `reader`
    /// flag tells those states apart).
    client: Option<Client>,
    /// Bumped on every dial and every recycle. A waiter whose epoch
    /// no longer matches knows its response died with the old
    /// connection and must fail (the caller fails over).
    epoch: u64,
    /// A reader currently has the client checked out.
    reader: bool,
}

impl PipeSlot {
    fn new() -> Self {
        Self {
            state: Mutex::new(PipeState {
                client: None,
                epoch: 0,
                reader: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Drop the slot's connection (responses in flight on it are
    /// lost; their waiters see the epoch change and error out).
    fn recycle(&self) {
        let mut st = self.state.lock().unwrap();
        st.client = None;
        st.epoch += 1;
        self.cv.notify_all();
    }
}

/// One backend endpoint and the router's view of it.
struct Backend {
    addr: String,
    /// Probed healthy (optimistically true at startup; the first
    /// failed probe or failed call clears it, a later successful
    /// probe restores it).
    alive: AtomicBool,
    /// Draining: finishes in-flight work, receives no new work.
    draining: AtomicBool,
    /// Last health pass saw a canary inference mismatch its
    /// refcompute oracle: the socket answers, the bits are wrong.
    /// Excluded from routing while set; a later passing canary
    /// clears it.
    canary_failed: AtomicBool,
    /// Router-observed requests currently in flight (the least-loaded
    /// dispatch signal).
    in_flight: AtomicUsize,
    served: AtomicU64,
    errors: AtomicU64,
    /// Fresh connections dialed by routed calls (both pools; health
    /// probes deliberately dial their own and are not counted). The
    /// cluster_properties suite pins connection reuse with this.
    dials: AtomicU64,
    /// Idle pooled connections for admin/observability calls, reused
    /// across calls.
    pool: Mutex<Vec<Client>>,
    /// Pipelined wire-v2 connections for `Infer` dispatch, sized by
    /// [`ClusterConfig::pipe_conns`].
    pipes: Vec<PipeSlot>,
    /// Round-robin cursor over `pipes`.
    next_pipe: AtomicUsize,
    /// Models the last health probe saw loaded.
    loaded: Mutex<BTreeSet<String>>,
}

impl Backend {
    fn new(addr: String, pipe_conns: usize) -> Self {
        Self {
            addr,
            alive: AtomicBool::new(true),
            draining: AtomicBool::new(false),
            canary_failed: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            served: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            dials: AtomicU64::new(0),
            pool: Mutex::new(Vec::new()),
            pipes: (0..pipe_conns.max(1)).map(|_| PipeSlot::new()).collect(),
            next_pipe: AtomicUsize::new(0),
            loaded: Mutex::new(BTreeSet::new()),
        }
    }

    fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    fn is_canary_failed(&self) -> bool {
        self.canary_failed.load(Ordering::SeqCst)
    }

    /// Routable: may receive *new* work. A failed canary excludes a
    /// backend exactly like a dead socket — wrong answers served
    /// fast are worse than no answers.
    fn routable(&self) -> bool {
        self.is_alive() && !self.is_draining() && !self.is_canary_failed()
    }

    fn mark_dead(&self) {
        self.alive.store(false, Ordering::SeqCst);
        // pooled connections to a dead backend are stale
        self.pool.lock().unwrap().clear();
        for slot in &self.pipes {
            slot.recycle();
        }
    }
}

/// FNV-1a 64: small, dependency-free, and plenty uniform for
/// spreading model names over a handful of backends.
fn fnv1a(init: u64, bytes: &[u8]) -> u64 {
    let mut h = init;
    for b in bytes {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Rendezvous weight of `(model, addr)`: each model ranks every
/// backend by this score; the top `replication` are its owners. A
/// backend joining or dying only moves the models it scores highest
/// for — no global reshuffle.
fn rendezvous_score(model: &str, addr: &str) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    let h = fnv1a(FNV_OFFSET, model.as_bytes());
    let h = fnv1a(h, &[0xff]);
    fnv1a(h, addr.as_bytes())
}

struct RouterInner {
    backends: Vec<Arc<Backend>>,
    cfg: ClusterConfig,
    /// The cluster's manifest: every model loaded *through the
    /// router*, with the spec failover re-loads it from.
    models: Mutex<BTreeMap<String, ModelSpec>>,
    conns_refused: AtomicU64,
}

/// The cluster frontend. Implements [`api::Dispatcher`], so
/// `serve::net::NetServer::bind` serves a cluster exactly like it
/// serves one process.
pub struct Router {
    inner: Arc<RouterInner>,
    stop: Arc<AtomicBool>,
    health: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// Point-in-time view of one backend, for `domino cluster status`.
#[derive(Clone, Debug)]
pub struct BackendStatus {
    pub addr: String,
    pub alive: bool,
    pub draining: bool,
    /// The backend answers its socket but its canary inference
    /// mismatched refcompute — silently corrupt, excluded from
    /// routing. Disjoint failure mode from `alive: false`.
    pub canary_failed: bool,
    pub in_flight: u64,
    pub served: u64,
    pub errors: u64,
    /// Fresh connections the router has dialed to this backend for
    /// routed calls. With pooling working, this stays near the pool
    /// sizes no matter how many requests flow.
    pub dials: u64,
    pub loaded: Vec<String>,
}

/// Point-in-time view of the cluster, for `domino cluster status`.
#[derive(Clone, Debug)]
pub struct ClusterStatus {
    pub backends: Vec<BackendStatus>,
    /// model → its current owner addresses, in rendezvous order.
    pub assignments: Vec<(String, Vec<String>)>,
}

impl ClusterStatus {
    /// Render for the CLI.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("backends ({}):\n", self.backends.len()));
        for b in &self.backends {
            let state = if !b.alive {
                "DEAD"
            } else if b.canary_failed {
                "canary-failed"
            } else if b.draining {
                "draining"
            } else {
                "alive"
            };
            out.push_str(&format!(
                "  {:<22} {:<13} in-flight {:>3}  served {:>6}  errors {:>4}  \
                 dials {:>4}  [{}]\n",
                b.addr,
                state,
                b.in_flight,
                b.served,
                b.errors,
                b.dials,
                b.loaded.join(", ")
            ));
        }
        out.push_str(&format!("assignments ({}):\n", self.assignments.len()));
        for (model, owners) in &self.assignments {
            out.push_str(&format!("  {:<14} -> {}\n", model, owners.join(", ")));
        }
        out
    }
}

impl Router {
    /// Build a router over `backends` (TCP addresses of running
    /// `domino serve` processes). No connections are opened here;
    /// backends start optimistically alive and the first probe or
    /// call corrects the picture. Call [`Self::start_health`] to
    /// begin probing.
    pub fn new(backends: Vec<String>, cfg: ClusterConfig) -> Result<Self> {
        if backends.is_empty() {
            bail!("a cluster needs at least one backend address");
        }
        let mut seen = BTreeSet::new();
        for b in &backends {
            if !seen.insert(b.clone()) {
                bail!("duplicate backend address {b:?}");
            }
        }
        let pipe_conns = cfg.pipe_conns.max(1);
        Ok(Self {
            inner: Arc::new(RouterInner {
                backends: backends
                    .into_iter()
                    .map(|a| Arc::new(Backend::new(a, pipe_conns)))
                    .collect(),
                cfg,
                models: Mutex::new(BTreeMap::new()),
                conns_refused: AtomicU64::new(0),
            }),
            stop: Arc::new(AtomicBool::new(false)),
            health: Mutex::new(None),
        })
    }

    /// Start the health thread: probe every backend each
    /// [`ClusterConfig::health_interval`], mark the unresponsive dead,
    /// resurrect the recovered, and re-load owned models onto owners
    /// missing them (the failover repair loop).
    pub fn start_health(&self) {
        let mut slot = self.health.lock().unwrap();
        if slot.is_some() {
            return;
        }
        let inner = Arc::clone(&self.inner);
        let stop = Arc::clone(&self.stop);
        let handle = std::thread::Builder::new()
            .name("domino-cluster-health".to_string())
            .spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    inner.probe_all();
                    inner.reconcile();
                    let interval = inner.cfg.health_interval;
                    let mut slept = Duration::ZERO;
                    // nap in small steps so shutdown is prompt
                    while slept < interval && !stop.load(Ordering::SeqCst) {
                        let step = Duration::from_millis(20).min(interval - slept);
                        std::thread::sleep(step);
                        slept += step;
                    }
                }
            });
        match handle {
            Ok(h) => *slot = Some(h),
            Err(e) => eprintln!("domino-cluster: spawn health thread: {e}"),
        }
    }

    /// Run exactly one health pass inline (probe + repair). Useful
    /// where a test or tool wants deterministic reconciliation
    /// instead of a background cadence.
    pub fn health_pass(&self) {
        self.inner.probe_all();
        self.inner.reconcile();
    }

    /// Probe-only pass: liveness and canary checks without the
    /// repair loop. `domino cluster status` uses this to observe
    /// (including the canary-failed state) without loading models
    /// onto anything.
    pub fn probe_pass(&self) {
        self.inner.probe_all();
    }

    /// Drain-aware removal: `addr` stops receiving new work, its
    /// in-flight requests finish (bounded by `deadline`), then it is
    /// marked dead and its models are re-loaded onto the owners that
    /// take over. Returns an error only for an unknown address; a
    /// drain that times out still completes the removal (the
    /// remaining in-flight calls fail over like any transport error).
    pub fn drain(&self, addr: &str, deadline: Duration) -> Result<()> {
        let be = self
            .inner
            .backends
            .iter()
            .find(|b| b.addr == addr)
            .ok_or_else(|| anyhow!("no backend with address {addr:?}"))?;
        be.draining.store(true, Ordering::SeqCst);
        let t0 = std::time::Instant::now();
        while be.in_flight.load(Ordering::SeqCst) > 0 && t0.elapsed() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        be.mark_dead();
        self.inner.reconcile();
        Ok(())
    }

    /// The router's current view: per-backend state and per-model
    /// owner assignments.
    pub fn status(&self) -> ClusterStatus {
        let backends = self
            .inner
            .backends
            .iter()
            .map(|b| BackendStatus {
                addr: b.addr.clone(),
                alive: b.is_alive(),
                draining: b.is_draining(),
                canary_failed: b.is_canary_failed(),
                in_flight: b.in_flight.load(Ordering::SeqCst) as u64,
                served: b.served.load(Ordering::SeqCst),
                errors: b.errors.load(Ordering::SeqCst),
                dials: b.dials.load(Ordering::Relaxed),
                loaded: b.loaded.lock().unwrap().iter().cloned().collect(),
            })
            .collect();
        let assignments = self
            .inner
            .models
            .lock()
            .unwrap()
            .keys()
            .map(|m| {
                (
                    m.clone(),
                    self.inner.owners(m).iter().map(|b| b.addr.clone()).collect(),
                )
            })
            .collect();
        ClusterStatus {
            backends,
            assignments,
        }
    }

    /// Backend addresses, in table order.
    pub fn backend_addrs(&self) -> Vec<String> {
        self.inner.backends.iter().map(|b| b.addr.clone()).collect()
    }

    /// Record `models` in the router's table without loading them
    /// anywhere — `domino cluster status` uses this to display the
    /// owner assignments the router *would* use for models it did not
    /// load itself. Names already in the table keep their recorded
    /// (seed, mapping) spec.
    pub fn assume_models(&self, models: &[String]) {
        let mut table = self.inner.models.lock().unwrap();
        for m in models {
            table.entry(RouterInner::canonical(m)).or_default();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.health.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Dispatcher for Router {
    fn dispatch(&self, req: Request) -> Response {
        self.inner.dispatch(req)
    }

    fn note_conn_refused(&self) {
        self.inner.conns_refused.fetch_add(1, Ordering::Relaxed);
    }
}

impl RouterInner {
    /// Canonicalize a model name the way a leaf service does, so the
    /// rendezvous hash sees one spelling (`TINY_CNN` and `tiny-cnn`
    /// must not land on different shards).
    fn canonical(model: &str) -> String {
        match zoo::by_name(model) {
            Some(net) => net.name,
            None => model.to_string(),
        }
    }

    /// The model's owner set: routable backends ranked by rendezvous
    /// score, top `replication`.
    fn owners(&self, model: &str) -> Vec<Arc<Backend>> {
        let mut ranked: Vec<&Arc<Backend>> =
            self.backends.iter().filter(|b| b.routable()).collect();
        ranked.sort_by_key(|b| std::cmp::Reverse(rendezvous_score(model, &b.addr)));
        ranked
            .into_iter()
            .take(self.cfg.replication.max(1))
            .cloned()
            .collect()
    }

    /// One routed call over a pooled connection. `Infer` rides the
    /// backend's pipelined pool (many in flight per socket, claimed
    /// by request id); everything else uses a plain synchronous
    /// pooled connection. A transport error marks the backend dead
    /// (the caller decides whether to fail over); a typed
    /// `Response::Error` is a *successful* call.
    fn call_backend(&self, be: &Backend, req: &Request) -> Result<Response> {
        be.in_flight.fetch_add(1, Ordering::SeqCst);
        let result = match req {
            Request::Infer { .. } => self.call_piped(be, req),
            _ => self.call_pooled(be, req),
        };
        be.in_flight.fetch_sub(1, Ordering::SeqCst);
        match &result {
            Ok(_) => {
                be.served.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                be.errors.fetch_add(1, Ordering::Relaxed);
                be.mark_dead();
            }
        }
        result
    }

    fn call_pooled(&self, be: &Backend, req: &Request) -> Result<Response> {
        let mut client = match be.pool.lock().unwrap().pop() {
            Some(c) => c,
            None => self.dial(be)?,
        };
        match client.call(req) {
            Ok(resp) => {
                be.pool.lock().unwrap().push(client);
                Ok(resp)
            }
            // the client poisoned itself; drop it, never re-pool it
            Err(e) => Err(e),
        }
    }

    /// Open a fresh routed-call connection to `be`, counted in
    /// [`BackendStatus::dials`]. Bounded backoff: ride out a
    /// transient refusal (a backend mid-restart) without hammering
    /// it, give up with a typed error so the caller fails over.
    fn dial(&self, be: &Backend) -> Result<Client> {
        be.dials.fetch_add(1, Ordering::Relaxed);
        let mut c = Client::connect_with_backoff(
            &be.addr,
            self.cfg.connect_attempts,
            self.cfg.connect_backoff,
        )?;
        c.set_read_timeout(Some(self.cfg.request_timeout))?;
        Ok(c)
    }

    /// One `Infer` round-trip over the backend's pipelined pool:
    /// submit tagged with a fresh request id on a round-robin slot,
    /// release the slot lock, claim the response by id. See
    /// [`PipeSlot`] for the leader/follower protocol that lets many
    /// router threads share one socket. Any transport error recycles
    /// the slot — the next call re-dials — and fails every response
    /// still in flight on it; the caller marks the backend dead and
    /// fails over exactly like the unpooled path.
    fn call_piped(&self, be: &Backend, req: &Request) -> Result<Response> {
        let idx = be.next_pipe.fetch_add(1, Ordering::Relaxed) % be.pipes.len();
        let slot = &be.pipes[idx];
        let mut st = slot.state.lock().unwrap();
        // a reader has the client checked out: queue until it is back
        while st.reader {
            st = slot.cv.wait(st).unwrap();
        }
        if st.client.is_none() {
            let c = match self.dial(be) {
                Ok(c) => c,
                Err(e) => {
                    slot.cv.notify_all();
                    return Err(e);
                }
            };
            st.client = Some(c);
            st.epoch += 1;
        }
        let my_epoch = st.epoch;
        let rid = match st.client.as_mut().unwrap().submit(req) {
            Ok(rid) => rid,
            Err(e) => {
                st.client = None;
                st.epoch += 1;
                slot.cv.notify_all();
                return Err(e);
            }
        };
        loop {
            if st.epoch != my_epoch {
                bail!(
                    "pipelined connection to {} was recycled with request id {rid} in flight",
                    be.addr
                );
            }
            if let Some(client) = st.client.as_mut() {
                if let Some(resp) = client.take_ready(rid) {
                    slot.cv.notify_all();
                    return Ok(resp);
                }
            }
            if st.reader {
                st = slot.cv.wait(st).unwrap();
                continue;
            }
            // become the reader: check the client out so the lock is
            // not held across the blocking read (submitters queue on
            // the condvar, not behind a socket)
            let mut client = st
                .client
                .take()
                .expect("pipe slot invariant: matching epoch and no reader implies a client");
            st.reader = true;
            drop(st);
            let result = client.await_response(rid);
            st = slot.state.lock().unwrap();
            st.reader = false;
            match result {
                Ok(resp) => {
                    // give the client back (other ids' parked
                    // responses ride inside it) unless the slot was
                    // recycled while we were reading — our own answer
                    // is still valid either way
                    if st.epoch == my_epoch {
                        st.client = Some(client);
                    }
                    slot.cv.notify_all();
                    return Ok(resp);
                }
                Err(e) => {
                    if st.epoch == my_epoch {
                        st.client = None;
                        st.epoch += 1;
                    }
                    slot.cv.notify_all();
                    return Err(e);
                }
            }
        }
    }

    /// Re-load `model` on `be` from the router's recorded spec.
    /// Tolerates "already loaded": two repair paths racing is fine.
    fn ensure_loaded(&self, be: &Backend, model: &str) -> Result<()> {
        let spec = self
            .models
            .lock()
            .unwrap()
            .get(model)
            .cloned()
            .ok_or_else(|| anyhow!("model {model:?} is not in the router's table"))?;
        let req = match spec.seed {
            Some(seed) => Request::LoadSeeded {
                model: model.to_string(),
                seed,
                mapping: spec.mapping,
            },
            None => Request::Load {
                model: model.to_string(),
                mapping: spec.mapping,
            },
        };
        match self.call_backend(be, &req)? {
            Response::Loaded(_) => {
                be.loaded.lock().unwrap().insert(model.to_string());
                Ok(())
            }
            Response::Error { message } if message.contains("already loaded") => {
                be.loaded.lock().unwrap().insert(model.to_string());
                Ok(())
            }
            Response::Error { message } => bail!("load {model} on {}: {message}", be.addr),
            other => bail!("unexpected response to load: {other:?}"),
        }
    }

    /// Probe every backend: `ListModels` doubles as liveness check
    /// and loaded-set report. A fresh connection per probe, so a
    /// backend that died and restarted is re-discovered without
    /// fighting stale pooled sockets. With [`ClusterConfig::canary`]
    /// on, the same connection then runs one seeded canary inference
    /// per owned model: a mismatch against the refcompute oracle
    /// marks the backend canary-failed (excluded from routing until
    /// a later canary passes), which is how a silently-corrupting
    /// tile fails over despite answering every liveness probe.
    fn probe_all(&self) {
        let table: BTreeSet<String> = self.models.lock().unwrap().keys().cloned().collect();
        for be in &self.backends {
            if be.is_draining() && !be.is_alive() {
                continue; // drained and removed; leave it dead
            }
            let probe = (|| -> Result<(Client, Vec<String>)> {
                let mut c = Client::connect(&be.addr)?;
                c.set_read_timeout(Some(self.cfg.health_timeout))?;
                let names = c.models()?.into_iter().map(|d| d.name).collect();
                Ok((c, names))
            })();
            match probe {
                Ok((mut c, names)) => {
                    *be.loaded.lock().unwrap() = names.iter().cloned().collect();
                    be.alive.store(true, Ordering::SeqCst);
                    if self.cfg.canary {
                        self.canary_backend(be, &mut c, &names, &table);
                    }
                }
                Err(_) => be.mark_dead(),
            }
        }
    }

    /// Canary every model of `names` the router knows about, over the
    /// already-open probe connection. Sets or clears the backend's
    /// canary flag from what this pass actually observed; a transport
    /// death mid-canary is an ordinary liveness failure. A backend
    /// too old to know the `Canary` request answers with a typed
    /// error — treated as "no canary coverage", not as corruption.
    fn canary_backend(
        &self,
        be: &Backend,
        c: &mut Client,
        names: &[String],
        table: &BTreeSet<String>,
    ) {
        let mut failed = false;
        for name in names.iter().filter(|n| table.contains(n.as_str())) {
            match c.call(&Request::Canary {
                model: name.clone(),
                seed: CANARY_SEED,
                heal: false,
            }) {
                Ok(Response::Canary(v)) if !v.ok => {
                    eprintln!(
                        "domino-cluster: canary failed on {} for {name}: \
                         {}/{} outputs wrong",
                        be.addr, v.mismatched, v.outputs
                    );
                    failed = true;
                }
                Ok(_) => {}
                Err(_) => {
                    be.mark_dead();
                    return;
                }
            }
        }
        be.canary_failed.store(failed, Ordering::SeqCst);
    }

    /// The repair loop: every model in the router's table must be
    /// loaded on every backend in its (current) owner set. After a
    /// backend dies, its models' owner sets re-rank over the
    /// survivors and this loop re-loads them there from the recorded
    /// spec — bit-identical weights, because weights are a pure
    /// function of (network, seed). Non-owners keep whatever they
    /// have: a stale replica is harmless and a future owner-set shift
    /// may want it back.
    fn reconcile(&self) {
        let models: Vec<String> = self.models.lock().unwrap().keys().cloned().collect();
        for model in models {
            for be in self.owners(&model) {
                let have = be.loaded.lock().unwrap().contains(&model);
                if !have {
                    if let Err(e) = self.ensure_loaded(&be, &model) {
                        eprintln!("domino-cluster: repair {model} on {}: {e:#}", be.addr);
                    }
                }
            }
        }
    }

    fn dispatch(&self, req: Request) -> Response {
        let r = match req {
            Request::Infer { model, image } => self.route_infer(model, image),
            req @ (Request::Load { .. } | Request::LoadSeeded { .. } | Request::Swap { .. }) => {
                self.route_admin(req)
            }
            Request::Unload { model } => self.route_unload(&model),
            Request::ListModels => self.route_list(),
            Request::ModelInfo { model } => self.route_to_primary(Request::ModelInfo { model }),
            Request::Stats => self.route_stats(),
            req @ (Request::Trace { .. }
            | Request::FaultInject { .. }
            | Request::Canary { .. }) => self.route_to_primary(req),
        };
        r.unwrap_or_else(|e| Response::Error {
            message: format!("{e:#}"),
        })
    }

    /// Data plane: least-loaded replica first, transport failures
    /// fail over to the next replica (an infer is idempotent — same
    /// weights, same image, same logits — so a retry can never serve
    /// a different answer), and an owner that is missing the model is
    /// repaired in-line and retried once.
    fn route_infer(&self, model: Option<String>, image: Vec<i8>) -> Result<Response> {
        let name = match model {
            Some(m) => Self::canonical(&m),
            None => {
                // `model: None` means "the sole model" — only
                // unambiguous when the cluster serves exactly one
                let models = self.models.lock().unwrap();
                match models.len() {
                    1 => models.keys().next().cloned().unwrap(),
                    0 => bail!("no model is loaded in the cluster"),
                    n => bail!(
                        "cluster serves {n} models; infer requests must name one"
                    ),
                }
            }
        };
        let mut owners = self.owners(&name);
        if owners.is_empty() {
            bail!("no live backend available for model {name:?}");
        }
        owners.sort_by_key(|b| b.in_flight.load(Ordering::SeqCst));
        let req = Request::Infer {
            model: Some(name.clone()),
            image,
        };
        let mut last_err = None;
        for be in &owners {
            match self.call_backend(be, &req) {
                Ok(Response::Error { message })
                    if message.contains("not loaded") || message.contains("no model") =>
                {
                    // the owner exists but lost the model (fresh
                    // failover target): repair and retry once
                    if self.ensure_loaded(be, &name).is_ok() {
                        if let Ok(resp) = self.call_backend(be, &req) {
                            return Ok(resp);
                        }
                    }
                    last_err = Some(anyhow!("{}: {message}", be.addr));
                }
                Ok(resp) => return Ok(resp),
                Err(e) => last_err = Some(anyhow!("{}: {e:#}", be.addr)),
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow!("no replica of {name:?} answered")))
    }

    /// Admin plane: fan to the owner set, record the spec on success.
    /// All owners must apply the mutation; partial success is a typed
    /// error naming the stragglers (the health repair loop will keep
    /// retrying them).
    fn route_admin(&self, req: Request) -> Result<Response> {
        let (name, spec) = match &req {
            Request::Load { model, mapping } => (
                Self::canonical(model),
                ModelSpec {
                    seed: None,
                    mapping: *mapping,
                },
            ),
            Request::LoadSeeded {
                model,
                seed,
                mapping,
            } => (
                Self::canonical(model),
                ModelSpec {
                    seed: Some(*seed),
                    mapping: *mapping,
                },
            ),
            Request::Swap { model, seed } => {
                let name = Self::canonical(model);
                let prior = self.models.lock().unwrap().get(&name).cloned();
                (
                    name,
                    ModelSpec {
                        seed: *seed,
                        mapping: prior.and_then(|s| s.mapping),
                    },
                )
            }
            _ => unreachable!("route_admin only handles Load/LoadSeeded/Swap"),
        };
        let owners = self.owners(&name);
        if owners.is_empty() {
            bail!("no live backend available for model {name:?}");
        }
        let mut ok_resp = None;
        let mut failures = Vec::new();
        for be in &owners {
            match self.call_backend(be, &req) {
                Ok(Response::Error { message }) => {
                    failures.push(format!("{}: {message}", be.addr))
                }
                Ok(resp) => {
                    be.loaded.lock().unwrap().insert(name.clone());
                    ok_resp = Some(resp);
                }
                Err(e) => failures.push(format!("{}: {e:#}", be.addr)),
            }
        }
        match (ok_resp, failures.is_empty()) {
            (Some(resp), true) => {
                self.models.lock().unwrap().insert(name, spec);
                Ok(resp)
            }
            (Some(_), false) => {
                // applied somewhere: record it (the repair loop will
                // chase the stragglers) but tell the operator
                self.models.lock().unwrap().insert(name.clone(), spec);
                bail!(
                    "{name} applied on {} of {} owners; failed on: {}",
                    owners.len() - failures.len(),
                    owners.len(),
                    failures.join("; ")
                )
            }
            (None, _) => bail!(
                "{name} failed on every owner: {}",
                failures.join("; ")
            ),
        }
    }

    /// Unload fans to *every* live backend — owner sets shift over
    /// time, so stale replicas may exist anywhere. "Not loaded" is
    /// success for this purpose.
    fn route_unload(&self, model: &str) -> Result<Response> {
        let name = Self::canonical(model);
        let req = Request::Unload {
            model: name.clone(),
        };
        let mut ok_resp = None;
        for be in &self.backends {
            if !be.is_alive() {
                continue;
            }
            if let Ok(resp) = self.call_backend(be, &req) {
                be.loaded.lock().unwrap().remove(&name);
                if matches!(resp, Response::Unloaded(_)) {
                    ok_resp = Some(resp);
                }
            }
        }
        self.models.lock().unwrap().remove(&name);
        ok_resp.ok_or_else(|| anyhow!("model {name:?} was not loaded on any live backend"))
    }

    /// Union of every live backend's models, deduplicated by name.
    fn route_list(&self) -> Result<Response> {
        let mut by_name: BTreeMap<String, api::ModelDesc> = BTreeMap::new();
        let mut any_alive = false;
        for be in &self.backends {
            if !be.is_alive() {
                continue;
            }
            if let Ok(Response::Models(descs)) = self.call_backend(be, &Request::ListModels) {
                any_alive = true;
                for d in descs {
                    by_name.entry(d.name.clone()).or_insert(d);
                }
            }
        }
        if !any_alive {
            bail!("no live backend answered ListModels");
        }
        Ok(Response::Models(by_name.into_values().collect()))
    }

    /// Model-specific calls route to the primary owner (rendezvous
    /// rank 0): one consistent answerer per model. The fault plane
    /// rides this path too — `FaultInject` arms the primary's local
    /// fault plan, `Canary` checks/heals the same backend the next
    /// infer would hit.
    fn route_to_primary(&self, req: Request) -> Result<Response> {
        let model = match &req {
            Request::ModelInfo { model }
            | Request::Trace { model, .. }
            | Request::FaultInject { model, .. }
            | Request::Canary { model, .. } => Self::canonical(model),
            _ => unreachable!("route_to_primary only handles model-addressed requests"),
        };
        // ranked over *alive* backends, deliberately including
        // canary-failed ones: the fault plane must reach a sick
        // primary to inspect or heal it — routing that excluded it
        // from new infer work must not also quarantine its cure
        let mut ranked: Vec<&Arc<Backend>> = self
            .backends
            .iter()
            .filter(|b| b.is_alive() && !b.is_draining())
            .collect();
        ranked.sort_by_key(|b| std::cmp::Reverse(rendezvous_score(&model, &b.addr)));
        let be = ranked
            .first()
            .ok_or_else(|| anyhow!("no live backend available for model {model:?}"))?;
        self.call_backend(be, &req)
    }

    /// Cluster-wide stats: counters summed across live backends,
    /// per-model metrics folded by name (counts summed, percentiles
    /// folded by max — a cluster p99 cannot be better than its worst
    /// replica's), plus the router's own refused-connection count.
    fn route_stats(&self) -> Result<Response> {
        let mut agg = StatsReply {
            served: 0,
            rejected: 0,
            failed: 0,
            conns_refused: self.conns_refused.load(Ordering::Relaxed),
            trace_rejected: 0,
            models: Vec::new(),
        };
        let mut by_name: BTreeMap<String, super::metrics::ModelMetricsSnapshot> =
            BTreeMap::new();
        let mut any_alive = false;
        for be in &self.backends {
            if !be.is_alive() {
                continue;
            }
            let Ok(Response::Stats(s)) = self.call_backend(be, &Request::Stats) else {
                continue;
            };
            any_alive = true;
            agg.served += s.served;
            agg.rejected += s.rejected;
            agg.failed += s.failed;
            agg.conns_refused += s.conns_refused;
            agg.trace_rejected += s.trace_rejected;
            for m in s.models {
                by_name
                    .entry(m.model.clone())
                    .and_modify(|acc| {
                        acc.served += m.served;
                        acc.failed += m.failed;
                        acc.rejected += m.rejected;
                        acc.traced += m.traced;
                        acc.queue_depth += m.queue_depth;
                        acc.samples += m.samples;
                        acc.p50_us = acc.p50_us.max(m.p50_us);
                        acc.p95_us = acc.p95_us.max(m.p95_us);
                        acc.p99_us = acc.p99_us.max(m.p99_us);
                        // OR-fold: one degraded replica degrades the
                        // cluster view of the model
                        acc.degraded = acc.degraded || m.degraded;
                    })
                    .or_insert(m);
            }
        }
        if !any_alive {
            bail!("no live backend answered Stats");
        }
        agg.models = by_name.into_values().collect();
        Ok(Response::Stats(agg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(addrs: &[&str], replication: usize) -> Router {
        Router::new(
            addrs.iter().map(|s| s.to_string()).collect(),
            ClusterConfig {
                replication,
                ..ClusterConfig::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn rendezvous_assignment_is_stable_and_survives_unrelated_removals() {
        let r = router(&["a:1", "b:2", "c:3", "d:4"], 2);
        let owners: Vec<String> = r
            .inner
            .owners("tiny-mlp")
            .iter()
            .map(|b| b.addr.clone())
            .collect();
        assert_eq!(owners.len(), 2);
        // deterministic: same answer every time
        for _ in 0..4 {
            let again: Vec<String> = r
                .inner
                .owners("tiny-mlp")
                .iter()
                .map(|b| b.addr.clone())
                .collect();
            assert_eq!(owners, again);
        }
        // different models spread: at least two distinct primary
        // owners across a handful of names (FNV over 4 backends)
        let primaries: BTreeSet<String> = ["tiny-mlp", "tiny-cnn", "tiny-resnet", "m4", "m5"]
            .iter()
            .map(|m| r.inner.owners(m)[0].addr.clone())
            .collect();
        assert!(primaries.len() >= 2, "all models on one backend: {primaries:?}");

        // killing a NON-owner must not move the model
        let non_owner = ["a:1", "b:2", "c:3", "d:4"]
            .iter()
            .find(|a| !owners.contains(&a.to_string()))
            .unwrap();
        r.inner
            .backends
            .iter()
            .find(|b| b.addr == *non_owner)
            .unwrap()
            .mark_dead();
        let after: Vec<String> = r
            .inner
            .owners("tiny-mlp")
            .iter()
            .map(|b| b.addr.clone())
            .collect();
        assert_eq!(owners, after, "losing a non-owner reshuffled the model");

        // killing an owner promotes exactly one survivor, keeps the other
        r.inner
            .backends
            .iter()
            .find(|b| b.addr == owners[0])
            .unwrap()
            .mark_dead();
        let failed_over: Vec<String> = r
            .inner
            .owners("tiny-mlp")
            .iter()
            .map(|b| b.addr.clone())
            .collect();
        assert_eq!(failed_over.len(), 2);
        assert!(failed_over.contains(&owners[1]), "surviving owner kept");
        assert!(!failed_over.contains(&owners[0]), "dead owner still ranked");
    }

    #[test]
    fn pipe_pool_is_sized_by_config_and_recycled_on_death() {
        let r = router(&["a:1", "b:2"], 1);
        let be = &r.inner.backends[0];
        assert_eq!(be.pipes.len(), ClusterConfig::default().pipe_conns);
        let e0 = be.pipes[0].state.lock().unwrap().epoch;
        // marking dead clears both pools and bumps every slot's epoch,
        // so waiters with responses in flight fail instead of hanging
        be.mark_dead();
        for slot in &be.pipes {
            let st = slot.state.lock().unwrap();
            assert!(st.client.is_none());
            assert!(!st.reader);
        }
        assert_eq!(be.pipes[0].state.lock().unwrap().epoch, e0 + 1);
        // pipe_conns is clamped: even 0 leaves one usable slot
        let r0 = Router::new(
            vec!["c:3".to_string()],
            ClusterConfig {
                pipe_conns: 0,
                ..ClusterConfig::default()
            },
        )
        .unwrap();
        assert_eq!(r0.inner.backends[0].pipes.len(), 1);
    }

    #[test]
    fn least_loaded_replica_is_picked_first() {
        let r = router(&["a:1", "b:2", "c:3"], 2);
        let owners = r.inner.owners("tiny-cnn");
        assert_eq!(owners.len(), 2);
        // tilt the load: first-ranked owner is busy
        owners[0].in_flight.store(5, Ordering::SeqCst);
        let mut sorted = owners.clone();
        sorted.sort_by_key(|b| b.in_flight.load(Ordering::SeqCst));
        assert_eq!(sorted[0].addr, owners[1].addr, "idle replica must rank first");
        // and with the tilt reversed, the order flips
        owners[0].in_flight.store(0, Ordering::SeqCst);
        owners[1].in_flight.store(7, Ordering::SeqCst);
        let mut sorted = owners.clone();
        sorted.sort_by_key(|b| b.in_flight.load(Ordering::SeqCst));
        assert_eq!(sorted[0].addr, owners[0].addr);
    }

    #[test]
    fn canary_failure_excludes_from_routing_but_renders_distinctly() {
        let r = router(&["a:1", "b:2", "c:3"], 2);
        let owners = r.inner.owners("tiny-mlp");
        let primary_addr = owners[0].addr.clone();
        let primary = r
            .inner
            .backends
            .iter()
            .find(|b| b.addr == primary_addr)
            .unwrap();
        // a failed canary excludes from routing exactly like death...
        primary.canary_failed.store(true, Ordering::SeqCst);
        assert!(primary.is_alive(), "canary failure is not a dead socket");
        assert!(!primary.routable());
        let after = r.inner.owners("tiny-mlp");
        assert!(after.iter().all(|b| b.addr != primary_addr));
        // ...but status tells the two states apart
        let status = r.status();
        let rendered = status.render();
        assert!(rendered.contains("canary-failed"), "{rendered}");
        assert!(!rendered.contains("DEAD"), "{rendered}");
        let bs = status
            .backends
            .iter()
            .find(|b| b.addr == primary_addr)
            .unwrap();
        assert!(bs.alive && bs.canary_failed);
        // a passing canary restores the backend
        primary.canary_failed.store(false, Ordering::SeqCst);
        assert!(primary.routable());
        assert!(!r.status().render().contains("canary-failed"));
    }

    #[test]
    fn drain_excludes_from_routing_and_duplicate_backends_are_rejected() {
        let r = router(&["a:1", "b:2", "c:3"], 2);
        let owners = r.inner.owners("tiny-mlp");
        let primary = owners[0].addr.clone();
        r.drain(&primary, Duration::from_millis(50)).unwrap();
        let after = r.inner.owners("tiny-mlp");
        assert!(after.iter().all(|b| b.addr != primary));
        assert!(r.drain("nope:0", Duration::ZERO).is_err());

        assert!(Router::new(
            vec!["x:1".to_string(), "x:1".to_string()],
            ClusterConfig::default()
        )
        .is_err());
    }
}
