//! The versioned model registry: compiled programs published under
//! names, with load / hot-swap / unload safe while serving. This is
//! the data plane's source of truth — requests resolve their
//! [`ModelVersion`] here at submit time and carry it through the
//! queue, so registry mutations never drop or reroute in-flight work.

use std::collections::btree_map::Entry;
use std::collections::{BTreeMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use anyhow::{anyhow, bail, Context, Result};

use super::api::MappingDesc;
use crate::coordinator::{ArchConfig, Compiler, Program, TileMask};
use crate::model::refcompute::Weights;
use crate::model::Network;

/// Compile `net` into a shared program + the exact weights it bakes in.
/// `weight_seed` of `None` uses the compiler's deterministic default
/// seed; a swap that must be *observable* passes a different seed.
fn compile_model(
    net: &Network,
    arch: ArchConfig,
    weight_seed: Option<u64>,
) -> Result<(Arc<Program>, Weights)> {
    let mut compiler = Compiler::new(arch);
    if let Some(seed) = weight_seed {
        compiler.weight_seed = seed;
    }
    let weights = Weights::random(net, compiler.weight_seed)?;
    let program = compiler.compile_with_weights(net, &weights)?;
    Ok((Arc::new(program), weights))
}

/// Compile `net` for the cycle-simulator backend with the compiler's
/// deterministic weight seed. Returns the shared program and the exact
/// weights it bakes in, so callers can cross-check every response
/// against `model::refcompute::forward` bit-for-bit.
pub fn sim_program(net: &Network, arch: ArchConfig) -> Result<(Arc<Program>, Weights)> {
    compile_model(net, arch, None)
}

/// One loaded, immutable model version: a compiled program plus the
/// weights baked into it (when the registry compiled it — prebuilt
/// programs may not carry weights). Versions are never mutated; a swap
/// publishes a *new* `ModelVersion` under the same name.
#[derive(Debug)]
pub struct ModelVersion {
    /// Globally unique id across the registry (every load and swap
    /// mints a fresh one) — the engine-pool cache key.
    id: u64,
    name: Arc<str>,
    /// Per-name version counter: 1 on load, +1 per swap.
    version: u64,
    program: Arc<Program>,
    weights: Option<Weights>,
    /// Mapping + placement stats, computed lazily once (the version is
    /// immutable, so `ModelInfo`/`ListModels` polling must not rerun
    /// the perfmodel + NoC flow analysis per request).
    mapping_desc: OnceLock<MappingDesc>,
}

impl ModelVersion {
    /// Globally unique id (fresh per load/swap; engine-pool key).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Registry name requests are routed by.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// 1 on first load, incremented by every swap of this name.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn program(&self) -> &Arc<Program> {
        &self.program
    }

    /// The arch (mapping) this version's program was compiled at —
    /// per-model, not the service-wide default.
    pub fn arch(&self) -> ArchConfig {
        self.program.arch
    }

    /// Mapping + placement stats of this version's program, computed
    /// on first use and cached for the version's lifetime.
    pub fn mapping_desc(&self) -> Result<&MappingDesc> {
        if let Some(m) = self.mapping_desc.get() {
            return Ok(m);
        }
        let m = MappingDesc::of_program(&self.program)?;
        // a concurrent initializer may have won the race; both computed
        // the same pure function of the immutable program
        Ok(self.mapping_desc.get_or_init(|| m))
    }

    /// The weights this version's program was compiled with (for
    /// refcompute cross-checks). `None` only for
    /// [`ModelRegistry::load_prebuilt`] entries registered without
    /// weights.
    pub fn weights(&self) -> Option<&Weights> {
        self.weights.as_ref()
    }

    /// Flat int8 input length this model accepts.
    pub fn input_len(&self) -> usize {
        self.program.net.input_len()
    }

    /// Lightweight identity stamp attached to every response.
    pub fn stamp(&self) -> ModelStamp {
        ModelStamp {
            name: Arc::clone(&self.name),
            id: self.id,
            version: self.version,
        }
    }

    /// Run the int8 reference network over one image with exactly this
    /// version's weights — the per-response correctness oracle used by
    /// the CLI, the load bench and the serving tests. Errors when the
    /// version was registered without weights
    /// ([`ModelRegistry::load_prebuilt`]).
    pub fn refcompute(&self, image: &[i8]) -> Result<Vec<i8>> {
        let weights = self.weights.as_ref().ok_or_else(|| {
            anyhow!("model {:?} was registered without weights", &*self.name)
        })?;
        let net = &self.program.net;
        let out = crate::model::refcompute::forward(
            net,
            weights,
            &crate::model::refcompute::Tensor::new(net.input, image.to_vec()),
        )?;
        Ok(out.data)
    }
}

/// Which model version served a response.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelStamp {
    pub name: Arc<str>,
    pub id: u64,
    pub version: u64,
}

/// A concurrent, versioned registry of compiled models, shared by the
/// serve workers (read side) and an admin path (load/swap/unload). All
/// operations are safe while the server is taking traffic; see the
/// module docs for the drain semantics.
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<ModelVersion>>>,
    next_id: AtomicU64,
    /// Monotonic mutation counter, bumped by every successful
    /// load/swap/unload. Workers compare it against the last value
    /// they saw to skip engine-cache pruning (and its read lock +
    /// allocation) on the steady-state serving path.
    generation: AtomicU64,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self {
            models: RwLock::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            generation: AtomicU64::new(0),
        }
    }

    /// Current mutation generation (bumped by load/swap/unload).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    fn bump_generation(&self) {
        self.generation.fetch_add(1, Ordering::Relaxed);
    }

    fn mint(
        &self,
        name: &str,
        version: u64,
        program: Arc<Program>,
        weights: Option<Weights>,
    ) -> Arc<ModelVersion> {
        Arc::new(ModelVersion {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            name: Arc::from(name),
            version,
            program,
            weights,
            mapping_desc: OnceLock::new(),
        })
    }

    /// Publish `mv` under a name that must still be vacant.
    fn publish_new(&self, name: &str, mv: &Arc<ModelVersion>) -> Result<()> {
        let mut m = self.models.write().unwrap();
        match m.entry(name.to_string()) {
            Entry::Occupied(_) => {
                bail!("model {name:?} is already loaded (use swap to replace it)")
            }
            Entry::Vacant(v) => {
                v.insert(Arc::clone(mv));
            }
        }
        drop(m);
        self.bump_generation();
        Ok(())
    }

    /// Compile `net` and publish it as `name` (version 1). Refuses a
    /// name that is already loaded — use [`Self::swap`] to replace.
    pub fn load(&self, name: &str, net: &Network, arch: ArchConfig) -> Result<Arc<ModelVersion>> {
        self.load_seeded(name, net, arch, None)
    }

    /// [`Self::load`] with an explicit weight seed.
    pub fn load_seeded(
        &self,
        name: &str,
        net: &Network,
        arch: ArchConfig,
        weight_seed: Option<u64>,
    ) -> Result<Arc<ModelVersion>> {
        self.load_restored(name, net, arch, weight_seed, 1)
    }

    /// [`Self::load_seeded`] publishing at an explicit starting
    /// `version` — the registry-persistence reload path, where a model
    /// that had been swapped to version N before the restart must come
    /// back as version N (its weights are reproduced from the recorded
    /// seed, so pre- and post-restart responses are bit-identical).
    pub fn load_restored(
        &self,
        name: &str,
        net: &Network,
        arch: ArchConfig,
        weight_seed: Option<u64>,
        version: u64,
    ) -> Result<Arc<ModelVersion>> {
        anyhow::ensure!(version >= 1, "model version must be >= 1 (got {version})");
        if self.get(name).is_some() {
            bail!("model {name:?} is already loaded (use swap to replace it)");
        }
        let (program, weights) =
            compile_model(net, arch, weight_seed).with_context(|| format!("compile {name:?}"))?;
        let mv = self.mint(name, version, program, Some(weights));
        self.publish_new(name, &mv)?;
        Ok(mv)
    }

    /// Publish an already-compiled program as `name` (version 1).
    /// `weights` may be `None` when the caller keeps its own copy for
    /// cross-checks.
    pub fn load_prebuilt(
        &self,
        name: &str,
        program: Arc<Program>,
        weights: Option<Weights>,
    ) -> Result<Arc<ModelVersion>> {
        let mv = self.mint(name, 1, program, weights);
        self.publish_new(name, &mv)?;
        Ok(mv)
    }

    /// Hot-swap `name` to a freshly compiled version of `net` (version
    /// bumped). Compilation happens outside the lock: traffic keeps
    /// serving the old version until the new one is published; requests
    /// already queued against the old version drain on it.
    pub fn swap(&self, name: &str, net: &Network, arch: ArchConfig) -> Result<Arc<ModelVersion>> {
        self.swap_seeded(name, net, arch, None)
    }

    /// [`Self::swap`] with an explicit weight seed (pass a new seed to
    /// make the swap observable in the outputs).
    pub fn swap_seeded(
        &self,
        name: &str,
        net: &Network,
        arch: ArchConfig,
        weight_seed: Option<u64>,
    ) -> Result<Arc<ModelVersion>> {
        if self.get(name).is_none() {
            bail!(
                "model {name:?} is not loaded (loaded: [{}])",
                self.names().join(", ")
            );
        }
        let (program, weights) =
            compile_model(net, arch, weight_seed).with_context(|| format!("compile {name:?}"))?;
        let mut m = self.models.write().unwrap();
        // Re-check under the write lock: a concurrent unload between
        // our pre-check and here must not turn a swap into a load.
        let Some(old_version) = m.get(name).map(|old| old.version) else {
            bail!("model {name:?} was unloaded during the swap");
        };
        let mv = self.mint(name, old_version + 1, program, Some(weights));
        m.insert(name.to_string(), Arc::clone(&mv));
        drop(m);
        self.bump_generation();
        Ok(mv)
    }

    /// Re-map `name` around a [`TileMask`] of known-bad tiles/links:
    /// the current version's **exact weights** are re-materialized
    /// onto a placement that provably avoids every masked resource,
    /// published as version+1 (same drain semantics as [`Self::swap`]
    /// — in-flight requests complete on the version they resolved).
    /// This is the fault-recovery path: outputs are weight-determined,
    /// so the re-mapped model is refcompute-bit-exact with the old one
    /// while the bad tiles go unused. Errors if the version was
    /// registered without weights ([`Self::load_prebuilt`]).
    pub fn remap_masked(&self, name: &str, mask: &TileMask) -> Result<Arc<ModelVersion>> {
        let Some(cur) = self.get(name) else {
            bail!(
                "model {name:?} is not loaded (loaded: [{}])",
                self.names().join(", ")
            );
        };
        let weights = cur
            .weights()
            .cloned()
            .ok_or_else(|| anyhow!("model {name:?} was registered without weights"))?;
        let net = cur.program().net.clone();
        // compile outside the lock, like swap: traffic keeps serving
        // the (possibly corrupting) old version until publish — the
        // caller marks the model degraded in the meantime
        let program = Compiler::new(cur.arch())
            .compile_with_weights_masked(&net, &weights, mask)
            .with_context(|| format!("re-map {name:?} around mask {mask}"))?;
        let mut m = self.models.write().unwrap();
        let Some(old_version) = m.get(name).map(|old| old.version) else {
            bail!("model {name:?} was unloaded during the re-map");
        };
        let mv = self.mint(name, old_version + 1, Arc::new(program), Some(weights));
        m.insert(name.to_string(), Arc::clone(&mv));
        drop(m);
        self.bump_generation();
        Ok(mv)
    }

    /// Remove `name`. Requests already accepted keep their
    /// `Arc<ModelVersion>` and complete normally; new submissions for
    /// the name are rejected.
    pub fn unload(&self, name: &str) -> Result<Arc<ModelVersion>> {
        let mut m = self.models.write().unwrap();
        match m.remove(name) {
            Some(mv) => {
                drop(m);
                self.bump_generation();
                Ok(mv)
            }
            None => {
                let names: Vec<&str> = m.keys().map(String::as_str).collect();
                bail!(
                    "model {name:?} is not loaded (loaded: [{}])",
                    names.join(", ")
                )
            }
        }
    }

    /// Current version published under `name`.
    pub fn get(&self, name: &str) -> Option<Arc<ModelVersion>> {
        self.models.read().unwrap().get(name).cloned()
    }

    /// The single loaded model, iff exactly one is loaded (the
    /// single-model `Server::submit` routing rule).
    pub fn sole(&self) -> Option<Arc<ModelVersion>> {
        let m = self.models.read().unwrap();
        if m.len() == 1 {
            m.values().next().cloned()
        } else {
            None
        }
    }

    /// All loaded versions, in name order.
    pub fn list(&self) -> Vec<Arc<ModelVersion>> {
        self.models.read().unwrap().values().cloned().collect()
    }

    /// Loaded names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.models.read().unwrap().keys().cloned().collect()
    }

    /// Ids of every currently-published version (engine-pool pruning).
    pub fn live_ids(&self) -> HashSet<u64> {
        self.models.read().unwrap().values().map(|m| m.id).collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.read().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NetworkBuilder, TensorShape};

    fn small_net() -> Network {
        NetworkBuilder::new("registry-test", TensorShape::new(2, 6, 6))
            .conv(4, 3, 1, 1)
            .flatten()
            .fc_logits(5)
            .build()
    }

    #[test]
    fn registry_load_swap_unload_lifecycle() {
        let registry = ModelRegistry::new();
        let net = small_net();
        let gen0 = registry.generation();
        let v1 = registry.load("alpha", &net, ArchConfig::default()).unwrap();
        assert!(registry.generation() > gen0, "load bumps the generation");
        assert_eq!(v1.version(), 1);
        assert_eq!(v1.name(), "alpha");
        assert_eq!(registry.names(), vec!["alpha".to_string()]);
        // duplicate load refused, pointing at swap
        let err = registry
            .load("alpha", &net, ArchConfig::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("swap"), "{err}");
        // swap of an unknown name lists what is loaded
        let err = registry
            .swap("nope", &net, ArchConfig::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("alpha"), "{err}");
        // swap bumps the version and mints a fresh id
        let v2 = registry.swap("alpha", &net, ArchConfig::default()).unwrap();
        assert_eq!(v2.version(), 2);
        assert_ne!(v2.id(), v1.id());
        // a seeded swap actually changes the weights
        let v3 = registry
            .swap_seeded("alpha", &net, ArchConfig::default(), Some(0xFEED))
            .unwrap();
        assert_eq!(v3.version(), 3);
        assert_ne!(
            v3.weights().unwrap().per_layer[0].as_slice(),
            v1.weights().unwrap().per_layer[0].as_slice(),
            "seeded swap must produce different weights"
        );
        // unload empties the registry; repeating it errors (and a
        // failed mutation leaves the generation alone)
        let gen_before = registry.generation();
        registry.unload("alpha").unwrap();
        assert!(registry.generation() > gen_before, "unload bumps the generation");
        assert!(registry.is_empty());
        let gen_after = registry.generation();
        assert!(registry.unload("alpha").is_err());
        assert_eq!(registry.generation(), gen_after);
        assert!(registry.get("alpha").is_none());
    }

    #[test]
    fn remap_masked_relocates_without_changing_outputs() {
        let registry = ModelRegistry::new();
        let net = small_net();
        let v1 = registry.load("m", &net, ArchConfig::default()).unwrap();
        let img = vec![2i8; net.input_len()];
        let before = v1.refcompute(&img).unwrap();

        // ban the first tile the base placement used
        let bad = v1.program().tile_coords()[0];
        let mut mask = TileMask::new();
        mask.ban_tile(bad);
        let v2 = registry.remap_masked("m", &mask).unwrap();

        assert_eq!(v2.version(), 2, "re-map publishes version+1");
        assert_ne!(v2.id(), v1.id(), "re-map mints a fresh pool key");
        assert!(
            v2.program().tile_coords().iter().all(|&c| c != bad),
            "masked tile must go unused"
        );
        // weights are carried over bit-exactly, so outputs match
        assert_eq!(v2.refcompute(&img).unwrap(), before);

        // unknown model and weight-less versions are typed errors
        assert!(registry.remap_masked("nope", &mask).is_err());
    }

    #[test]
    fn load_restored_reproduces_version_and_weights() {
        let net = small_net();
        let a = ModelRegistry::new();
        a.load_seeded("m", &net, ArchConfig::default(), Some(0xAB))
            .unwrap();
        let a3 = a
            .swap_seeded("m", &net, ArchConfig::default(), Some(0xCD))
            .unwrap();
        assert_eq!(a3.version(), 2);

        // "restart": a fresh registry restored from (seed, version)
        let b = ModelRegistry::new();
        let b3 = b
            .load_restored("m", &net, ArchConfig::default(), Some(0xCD), 2)
            .unwrap();
        assert_eq!(b3.version(), 2);
        let (aw, bw) = (a3.weights().unwrap(), b3.weights().unwrap());
        assert_eq!(aw.per_layer.len(), bw.per_layer.len());
        for (li, (x, y)) in aw.per_layer.iter().zip(&bw.per_layer).enumerate() {
            assert_eq!(
                x.as_slice(),
                y.as_slice(),
                "restored weights must be bit-identical (layer {li})"
            );
        }
        // and refcompute agrees on an actual image
        let img = vec![3i8; net.input_len()];
        assert_eq!(a3.refcompute(&img).unwrap(), b3.refcompute(&img).unwrap());

        // version 0 is invalid, duplicate restore refused
        assert!(b
            .load_restored("x", &net, ArchConfig::default(), None, 0)
            .is_err());
        assert!(b
            .load_restored("m", &net, ArchConfig::default(), None, 1)
            .is_err());
    }
}
