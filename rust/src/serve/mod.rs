//! Inference serving: the production-style request loop, with two
//! interchangeable execution backends behind one queue — and, on the
//! simulator backend, a multi-model registry with hot-swap.
//!
//! The server is a bounded request queue with backpressure, a
//! configurable pool of worker threads, micro-batched dequeueing and
//! latency/throughput accounting (p50/p95/p99). What executes a
//! dequeued micro-batch is the **backend**:
//!
//! * **PJRT** ([`Server::start`]) — each worker owns a private PJRT
//!   client executing the AOT-compiled JAX/Pallas artifact (`make
//!   artifacts`; the `xla` crate's raw handles are not `Send`, hence
//!   per-worker clients). Python is never on this path.
//! * **Cycle simulator** ([`Server::start_sim`], [`Server::start_multi`])
//!   — requests are routed by model tag through a shared
//!   [`ModelRegistry`] of compiled [`Program`]s; each worker keeps one
//!   warm [`crate::sim::PooledEngine`] per loaded model in a
//!   [`crate::sim::EnginePool`] (built once, tile state reset between
//!   images — never rebuilt per request or per batch). This serves the
//!   paper's cycle-accurate datapath end-to-end — submit → route →
//!   micro-batch → response — and is what
//!   `benches/serve_sim_throughput.rs` load-tests. Every response is
//!   stamped with the exact model *version* that served it
//!   ([`Response::model`]), so callers cross-check it bit-for-bit
//!   against `model::refcompute` with that version's weights
//!   ([`ModelVersion::weights`]): a routing bug is a correctness
//!   failure, not a silent misroute.
//!
//! ## Hot-swap semantics
//!
//! [`ModelRegistry::swap`] compiles the replacement *outside* the
//! registry lock, then atomically republishes the name. A request
//! resolves its model version at **submit** time and carries the
//! `Arc<ModelVersion>` through the queue, so swap/unload never drops or
//! reroutes in-flight work: requests accepted against the old version
//! drain on the old program, requests submitted after the swap run on
//! the new one. Workers prune engines of dead versions from their pools
//! after a micro-batch; a still-queued request of a pruned version just
//! rebuilds its engine on demand.
//!
//! Shutdown is graceful under load: workers drain the queue completely
//! before exiting, so every accepted request is resolved — answered on
//! success, or its response channel closed on a per-request execution
//! failure (the client's `recv` errors instead of hanging; workers
//! keep serving and the failure is counted in [`Server::failed`]).
//! The `stop` flag is published while holding the queue mutex — a
//! store outside the lock could land between a worker's emptiness
//! check and its `Condvar::wait`, and the notification would be lost
//! (the classic missed-wakeup race; regression-tested below).

use std::collections::btree_map::Entry;
use std::collections::{BTreeMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{ArchConfig, Compiler, Program};
use crate::model::refcompute::Weights;
use crate::model::Network;
use crate::sim::EnginePool;

/// Compile `net` into a shared program + the exact weights it bakes in.
/// `weight_seed` of `None` uses the compiler's deterministic default
/// seed; a swap that must be *observable* passes a different seed.
fn compile_model(
    net: &Network,
    arch: ArchConfig,
    weight_seed: Option<u64>,
) -> Result<(Arc<Program>, Weights)> {
    let mut compiler = Compiler::new(arch);
    if let Some(seed) = weight_seed {
        compiler.weight_seed = seed;
    }
    let weights = Weights::random(net, compiler.weight_seed)?;
    let program = compiler.compile_with_weights(net, &weights)?;
    Ok((Arc::new(program), weights))
}

/// Compile `net` for the cycle-simulator backend with the compiler's
/// deterministic weight seed. Returns the shared program and the exact
/// weights it bakes in, so callers can cross-check every response
/// against `model::refcompute::forward` bit-for-bit.
pub fn sim_program(net: &Network, arch: ArchConfig) -> Result<(Arc<Program>, Weights)> {
    compile_model(net, arch, None)
}

/// One loaded, immutable model version: a compiled program plus the
/// weights baked into it (when the registry compiled it — prebuilt
/// programs may not carry weights). Versions are never mutated; a swap
/// publishes a *new* `ModelVersion` under the same name.
#[derive(Debug)]
pub struct ModelVersion {
    /// Globally unique id across the registry (every load and swap
    /// mints a fresh one) — the engine-pool cache key.
    id: u64,
    name: Arc<str>,
    /// Per-name version counter: 1 on load, +1 per swap.
    version: u64,
    program: Arc<Program>,
    weights: Option<Weights>,
}

impl ModelVersion {
    /// Globally unique id (fresh per load/swap; engine-pool key).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Registry name requests are routed by.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// 1 on first load, incremented by every swap of this name.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn program(&self) -> &Arc<Program> {
        &self.program
    }

    /// The weights this version's program was compiled with (for
    /// refcompute cross-checks). `None` only for
    /// [`ModelRegistry::load_prebuilt`] entries registered without
    /// weights.
    pub fn weights(&self) -> Option<&Weights> {
        self.weights.as_ref()
    }

    /// Flat int8 input length this model accepts.
    pub fn input_len(&self) -> usize {
        self.program.net.input_len()
    }

    /// Lightweight identity stamp attached to every response.
    pub fn stamp(&self) -> ModelStamp {
        ModelStamp {
            name: Arc::clone(&self.name),
            id: self.id,
            version: self.version,
        }
    }

    /// Run the int8 reference network over one image with exactly this
    /// version's weights — the per-response correctness oracle used by
    /// the CLI, the load bench and the serving tests. Errors when the
    /// version was registered without weights
    /// ([`ModelRegistry::load_prebuilt`]).
    pub fn refcompute(&self, image: &[i8]) -> Result<Vec<i8>> {
        let weights = self.weights.as_ref().ok_or_else(|| {
            anyhow!("model {:?} was registered without weights", &*self.name)
        })?;
        let net = &self.program.net;
        let out = crate::model::refcompute::forward(
            net,
            weights,
            &crate::model::refcompute::Tensor::new(net.input, image.to_vec()),
        )?;
        Ok(out.data)
    }
}

/// Which model version served a response.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelStamp {
    pub name: Arc<str>,
    pub id: u64,
    pub version: u64,
}

/// A concurrent, versioned registry of compiled models, shared by the
/// serve workers (read side) and an admin path (load/swap/unload). All
/// operations are safe while the server is taking traffic; see the
/// module docs for the drain semantics.
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<ModelVersion>>>,
    next_id: AtomicU64,
    /// Monotonic mutation counter, bumped by every successful
    /// load/swap/unload. Workers compare it against the last value
    /// they saw to skip engine-cache pruning (and its read lock +
    /// allocation) on the steady-state serving path.
    generation: AtomicU64,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self {
            models: RwLock::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            generation: AtomicU64::new(0),
        }
    }

    /// Current mutation generation (bumped by load/swap/unload).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    fn bump_generation(&self) {
        self.generation.fetch_add(1, Ordering::Relaxed);
    }

    fn mint(
        &self,
        name: &str,
        version: u64,
        program: Arc<Program>,
        weights: Option<Weights>,
    ) -> Arc<ModelVersion> {
        Arc::new(ModelVersion {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            name: Arc::from(name),
            version,
            program,
            weights,
        })
    }

    /// Compile `net` and publish it as `name` (version 1). Refuses a
    /// name that is already loaded — use [`Self::swap`] to replace.
    pub fn load(&self, name: &str, net: &Network, arch: ArchConfig) -> Result<Arc<ModelVersion>> {
        self.load_seeded(name, net, arch, None)
    }

    /// [`Self::load`] with an explicit weight seed.
    pub fn load_seeded(
        &self,
        name: &str,
        net: &Network,
        arch: ArchConfig,
        weight_seed: Option<u64>,
    ) -> Result<Arc<ModelVersion>> {
        if self.get(name).is_some() {
            bail!("model {name:?} is already loaded (use swap to replace it)");
        }
        let (program, weights) =
            compile_model(net, arch, weight_seed).with_context(|| format!("compile {name:?}"))?;
        let mv = self.mint(name, 1, program, Some(weights));
        let mut m = self.models.write().unwrap();
        match m.entry(name.to_string()) {
            Entry::Occupied(_) => bail!("model {name:?} was loaded concurrently"),
            Entry::Vacant(v) => {
                v.insert(Arc::clone(&mv));
            }
        }
        drop(m);
        self.bump_generation();
        Ok(mv)
    }

    /// Publish an already-compiled program as `name` (version 1).
    /// `weights` may be `None` when the caller keeps its own copy for
    /// cross-checks.
    pub fn load_prebuilt(
        &self,
        name: &str,
        program: Arc<Program>,
        weights: Option<Weights>,
    ) -> Result<Arc<ModelVersion>> {
        let mv = self.mint(name, 1, program, weights);
        let mut m = self.models.write().unwrap();
        match m.entry(name.to_string()) {
            Entry::Occupied(_) => bail!("model {name:?} is already loaded (use swap to replace it)"),
            Entry::Vacant(v) => {
                v.insert(Arc::clone(&mv));
            }
        }
        drop(m);
        self.bump_generation();
        Ok(mv)
    }

    /// Hot-swap `name` to a freshly compiled version of `net` (version
    /// bumped). Compilation happens outside the lock: traffic keeps
    /// serving the old version until the new one is published; requests
    /// already queued against the old version drain on it.
    pub fn swap(&self, name: &str, net: &Network, arch: ArchConfig) -> Result<Arc<ModelVersion>> {
        self.swap_seeded(name, net, arch, None)
    }

    /// [`Self::swap`] with an explicit weight seed (pass a new seed to
    /// make the swap observable in the outputs).
    pub fn swap_seeded(
        &self,
        name: &str,
        net: &Network,
        arch: ArchConfig,
        weight_seed: Option<u64>,
    ) -> Result<Arc<ModelVersion>> {
        if self.get(name).is_none() {
            bail!(
                "model {name:?} is not loaded (loaded: [{}])",
                self.names().join(", ")
            );
        }
        let (program, weights) =
            compile_model(net, arch, weight_seed).with_context(|| format!("compile {name:?}"))?;
        let mut m = self.models.write().unwrap();
        // Re-check under the write lock: a concurrent unload between
        // our pre-check and here must not turn a swap into a load.
        let Some(old_version) = m.get(name).map(|old| old.version) else {
            bail!("model {name:?} was unloaded during the swap");
        };
        let mv = self.mint(name, old_version + 1, program, Some(weights));
        m.insert(name.to_string(), Arc::clone(&mv));
        drop(m);
        self.bump_generation();
        Ok(mv)
    }

    /// Remove `name`. Requests already accepted keep their
    /// `Arc<ModelVersion>` and complete normally; new submissions for
    /// the name are rejected.
    pub fn unload(&self, name: &str) -> Result<Arc<ModelVersion>> {
        let mut m = self.models.write().unwrap();
        match m.remove(name) {
            Some(mv) => {
                drop(m);
                self.bump_generation();
                Ok(mv)
            }
            None => {
                let names: Vec<&str> = m.keys().map(String::as_str).collect();
                bail!(
                    "model {name:?} is not loaded (loaded: [{}])",
                    names.join(", ")
                )
            }
        }
    }

    /// Current version published under `name`.
    pub fn get(&self, name: &str) -> Option<Arc<ModelVersion>> {
        self.models.read().unwrap().get(name).cloned()
    }

    /// The single loaded model, iff exactly one is loaded (the
    /// single-model [`Server::submit`] routing rule).
    pub fn sole(&self) -> Option<Arc<ModelVersion>> {
        let m = self.models.read().unwrap();
        if m.len() == 1 {
            m.values().next().cloned()
        } else {
            None
        }
    }

    /// All loaded versions, in name order.
    pub fn list(&self) -> Vec<Arc<ModelVersion>> {
        self.models.read().unwrap().values().cloned().collect()
    }

    /// Loaded names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.models.read().unwrap().keys().cloned().collect()
    }

    /// Ids of every currently-published version (engine-pool pruning).
    pub fn live_ids(&self) -> HashSet<u64> {
        self.models.read().unwrap().values().map(|m| m.id).collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.read().unwrap().is_empty()
    }
}

/// One inference request.
pub struct Request {
    pub id: u64,
    pub image: Vec<i8>,
    /// Model version resolved at submit time (`None` on the PJRT
    /// path). A swap or unload after submission does not affect this
    /// request: it executes on exactly this version (drain semantics).
    model: Option<Arc<ModelVersion>>,
    enqueued: Instant,
    resp: mpsc::Sender<Response>,
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<i8>,
    /// Exactly which model version served this request (`None` on the
    /// PJRT path). Cross-check `logits` against this version's weights.
    pub model: Option<ModelStamp>,
    /// Time spent queued before a worker picked the request up.
    pub queue: Duration,
    /// Executor time (batch time attributed per request).
    pub exec: Duration,
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker threads (each with a private execution engine pool).
    pub workers: usize,
    /// Max requests drained per dequeue (micro-batch).
    pub max_batch: usize,
    /// Queue capacity; `submit` fails fast beyond it (backpressure).
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 8,
            queue_cap: 256,
        }
    }
}

#[derive(Default)]
struct Shared {
    queue: Mutex<VecDeque<Request>>,
    cv: Condvar,
    stop: AtomicBool,
    served: AtomicU64,
    rejected: AtomicU64,
    /// Requests whose execution failed (the client's channel is closed
    /// instead of answered; workers keep serving).
    failed: AtomicU64,
}

/// Which execution engine the workers build (internal; selected by the
/// `Server` constructor used).
enum BackendSpec {
    /// AOT artifact through a per-worker PJRT client.
    Pjrt,
    /// Cycle-accurate engines over a shared model registry; requests
    /// are routed by the model version they carry.
    Sim(Arc<ModelRegistry>),
}

/// What a worker thread runs per request. `batch_done` fires after each
/// drained micro-batch (engine-cache pruning and similar bookkeeping).
trait Backend {
    fn infer(&mut self, req: &Request) -> Result<Vec<i8>>;
    fn batch_done(&mut self) {}
}

/// PJRT worker state: one full client per worker (handles aren't Send).
struct PjrtBackend {
    exe: crate::runtime::golden::TrainedTiny,
}

impl Backend for PjrtBackend {
    fn infer(&mut self, req: &Request) -> Result<Vec<i8>> {
        self.exe.run(&req.image)
    }
}

/// Simulator worker state: one warm engine per loaded model, keyed by
/// model-version id.
struct SimBackend {
    registry: Arc<ModelRegistry>,
    pool: EnginePool,
    /// Registry generation last reconciled against; pruning runs only
    /// when it moves, keeping the steady-state serving path free of
    /// registry locks and allocations.
    seen_generation: u64,
}

impl Backend for SimBackend {
    fn infer(&mut self, req: &Request) -> Result<Vec<i8>> {
        let mv = req
            .model
            .as_ref()
            .ok_or_else(|| anyhow!("sim request without a model tag"))?;
        let out = self.pool.engine(mv.id(), mv.program()).run_image(&req.image)?;
        Ok(out.scores)
    }

    fn batch_done(&mut self) {
        // Drop engines of swapped-away / unloaded versions so a dead
        // version's compiled program is released promptly (a
        // length-based check would miss a swap, which replaces a key
        // without changing the count and would pin the old program for
        // the process lifetime). Gated on the registry's mutation
        // generation so unchanged registries cost nothing here. A
        // still-queued request that holds a pruned version simply
        // rebuilds its engine on demand.
        let generation = self.registry.generation();
        if generation != self.seen_generation {
            self.seen_generation = generation;
            self.pool.retain_keys(&self.registry.live_ids());
        }
    }
}

/// A running inference server.
pub struct Server {
    shared: Arc<Shared>,
    cfg: ServeConfig,
    workers: Vec<std::thread::JoinHandle<Result<u64>>>,
    next_id: AtomicU64,
    input_len: usize,
    backend: &'static str,
    registry: Option<Arc<ModelRegistry>>,
}

impl Server {
    /// Start `cfg.workers` threads serving the trained tiny-cnn
    /// artifact over PJRT. Fails immediately if the artifacts are
    /// missing.
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        if !crate::runtime::artifacts_available() {
            bail!("artifacts not built (run `make artifacts`)");
        }
        Self::start_backend(cfg, BackendSpec::Pjrt, 3 * 16 * 16, "pjrt")
    }

    /// Start `cfg.workers` threads serving the cycle-accurate simulator
    /// over one shared compiled program (see [`sim_program`]). Needs no
    /// artifacts: the whole datapath is the Rust engine. Internally
    /// this is a single-entry [`ModelRegistry`] (named after the
    /// network), so [`Self::submit`] routes without a model tag.
    pub fn start_sim(cfg: ServeConfig, program: Arc<Program>) -> Result<Self> {
        let input_len = program.net.input_len();
        let registry = Arc::new(ModelRegistry::new());
        let name = program.net.name.clone();
        registry.load_prebuilt(&name, program, None)?;
        Self::start_backend(cfg, BackendSpec::Sim(registry), input_len, "sim")
    }

    /// Start `cfg.workers` threads serving every model in `registry`,
    /// with requests routed by model name ([`Self::submit_to`]) and
    /// hot-swap/load/unload available through the registry while
    /// serving. Each worker pre-builds one engine per model loaded at
    /// startup; models loaded later get engines lazily on first
    /// request.
    pub fn start_multi(cfg: ServeConfig, registry: Arc<ModelRegistry>) -> Result<Self> {
        anyhow::ensure!(
            !registry.is_empty(),
            "model registry has no models loaded"
        );
        let input_len = registry.sole().map(|m| m.input_len()).unwrap_or(0);
        Self::start_backend(cfg, BackendSpec::Sim(registry), input_len, "sim")
    }

    fn start_backend(
        cfg: ServeConfig,
        spec: BackendSpec,
        input_len: usize,
        backend: &'static str,
    ) -> Result<Self> {
        anyhow::ensure!(cfg.workers >= 1 && cfg.max_batch >= 1);
        let registry = match &spec {
            BackendSpec::Sim(r) => Some(Arc::clone(r)),
            BackendSpec::Pjrt => None,
        };
        let shared = Arc::new(Shared::default());
        let mut workers = Vec::with_capacity(cfg.workers);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let ready = ready_tx.clone();
            let max_batch = cfg.max_batch;
            let spec = match &spec {
                BackendSpec::Pjrt => BackendSpec::Pjrt,
                BackendSpec::Sim(r) => BackendSpec::Sim(Arc::clone(r)),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("domino-worker-{w}"))
                    .spawn(move || worker_entry(shared, max_batch, spec, ready))
                    .context("spawn worker")?,
            );
        }
        drop(ready_tx);
        // wait until every worker has built its execution engine(s)
        for _ in 0..cfg.workers {
            ready_rx
                .recv()
                .context("worker died during startup")??;
        }
        Ok(Self {
            shared,
            cfg,
            workers,
            next_id: AtomicU64::new(0),
            input_len,
            backend,
            registry,
        })
    }

    /// Flat input length this server accepts through [`Self::submit`]:
    /// the sole loaded model's input on the sim backend (tracking the
    /// live registry, so 0 once several models are loaded — use
    /// [`ModelVersion::input_len`] per model then), or the fixed
    /// artifact input on PJRT.
    pub fn input_len(&self) -> usize {
        match &self.registry {
            None => self.input_len,
            Some(reg) => reg.sole().map(|m| m.input_len()).unwrap_or(0),
        }
    }

    /// Which backend the workers run (`"pjrt"` or `"sim"`).
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// The model registry behind a sim server (`None` on PJRT). Use it
    /// to load/swap/unload models while serving.
    pub fn registry(&self) -> Option<&Arc<ModelRegistry>> {
        self.registry.as_ref()
    }

    /// Submit one image to the server's sole model; returns a receiver
    /// for the response. Fails fast when the queue is full
    /// (backpressure), the image is the wrong size, or more than one
    /// model is loaded (use [`Self::submit_to`] then).
    pub fn submit(&self, image: Vec<i8>) -> Result<mpsc::Receiver<Response>> {
        match &self.registry {
            None => self.enqueue(None, image),
            Some(reg) => {
                let mv = reg.sole().ok_or_else(|| {
                    anyhow!(
                        "{} models loaded ([{}]); name one with submit_to",
                        reg.len(),
                        reg.names().join(", ")
                    )
                })?;
                self.enqueue(Some(mv), image)
            }
        }
    }

    /// Submit one image to the named model. The model version is
    /// resolved now and travels with the request: a swap or unload
    /// between submit and execution does not affect it.
    pub fn submit_to(&self, model: &str, image: Vec<i8>) -> Result<mpsc::Receiver<Response>> {
        let reg = self.registry.as_ref().ok_or_else(|| {
            anyhow!(
                "the {} backend is single-model; use submit",
                self.backend
            )
        })?;
        let mv = reg.get(model).ok_or_else(|| {
            anyhow!(
                "model {model:?} is not loaded (loaded: [{}])",
                reg.names().join(", ")
            )
        })?;
        self.enqueue(Some(mv), image)
    }

    fn enqueue(
        &self,
        model: Option<Arc<ModelVersion>>,
        image: Vec<i8>,
    ) -> Result<mpsc::Receiver<Response>> {
        let want = model
            .as_ref()
            .map(|m| m.input_len())
            .unwrap_or(self.input_len);
        if image.len() != want {
            match &model {
                Some(m) => bail!(
                    "image for model {:?} must be {want} int8 values (got {})",
                    m.name(),
                    image.len()
                ),
                None => bail!("image must be {want} int8 values (got {})", image.len()),
            }
        }
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.cfg.queue_cap {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                bail!("queue full ({}): backpressure", self.cfg.queue_cap);
            }
            q.push_back(Request {
                id,
                image,
                model,
                enqueued: Instant::now(),
                resp: tx,
            });
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Synchronous convenience: submit + wait.
    pub fn infer(&self, image: Vec<i8>) -> Result<Response> {
        let rx = self.submit(image)?;
        rx.recv().context("worker dropped the request")
    }

    /// Synchronous convenience: submit to a named model + wait.
    pub fn infer_on(&self, model: &str, image: Vec<i8>) -> Result<Response> {
        let rx = self.submit_to(model, image)?;
        rx.recv().context("worker dropped the request")
    }

    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Requests whose execution failed after being accepted. Each one
    /// had its response channel closed (the client's `recv` errors)
    /// rather than hanging; the worker that hit the failure keeps
    /// serving.
    pub fn failed(&self) -> u64 {
        self.shared.failed.load(Ordering::Relaxed)
    }

    /// Stop workers and join them; returns per-worker served counts.
    ///
    /// Workers drain the queue before exiting, so every request
    /// accepted by `submit` before this call is still resolved —
    /// answered, or its channel closed if its execution failed. This
    /// holds with any number of models loaded, including versions
    /// unloaded or swapped away while their requests were queued.
    pub fn shutdown(mut self) -> Result<Vec<u64>> {
        {
            // Publish `stop` while holding the queue mutex: a worker is
            // either before its predicate check (it will see the flag)
            // or already parked in `wait` (it will see the notify).
            // Storing without the lock could slot between a worker's
            // check and its wait, losing the wakeup forever.
            let _q = self.shared.queue.lock().unwrap();
            self.shared.stop.store(true, Ordering::SeqCst);
        }
        self.shared.cv.notify_all();
        let mut counts = Vec::new();
        for w in self.workers.drain(..) {
            counts.push(w.join().map_err(|_| anyhow::anyhow!("worker panicked"))??);
        }
        Ok(counts)
    }
}

/// Worker thread entry: build the backend's execution engine(s), signal
/// readiness, then serve micro-batches until shutdown.
fn worker_entry(
    shared: Arc<Shared>,
    max_batch: usize,
    spec: BackendSpec,
    ready: mpsc::Sender<Result<()>>,
) -> Result<u64> {
    match spec {
        BackendSpec::Pjrt => {
            // each worker owns a full PJRT stack (handles are not Send)
            let init = (|| -> Result<crate::runtime::golden::TrainedTiny> {
                let rt = crate::runtime::Runtime::cpu()?;
                crate::runtime::golden::TrainedTiny::load(&rt)
            })();
            let exe = match init {
                Ok(e) => {
                    let _ = ready.send(Ok(()));
                    e
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    let _ = ready.send(Err(e));
                    bail!("worker init failed: {msg}");
                }
            };
            Ok(serve_loop(&shared, max_batch, PjrtBackend { exe }))
        }
        BackendSpec::Sim(registry) => {
            // Warm the per-worker engine cache for every model loaded
            // at startup, so `ready` means "engines built" (models
            // loaded later build lazily on their first request). The
            // generation is sampled *before* warming: a registry
            // mutation racing the warm-up is then caught by the first
            // batch_done prune.
            let seen_generation = registry.generation();
            let mut pool = EnginePool::new();
            for mv in registry.list() {
                pool.engine(mv.id(), mv.program());
            }
            let _ = ready.send(Ok(()));
            Ok(serve_loop(
                &shared,
                max_batch,
                SimBackend {
                    registry,
                    pool,
                    seen_generation,
                },
            ))
        }
    }
}

/// The backend-agnostic micro-batch loop: block until work or stop,
/// drain up to `max_batch` requests, execute, respond. Returns the
/// number of requests this worker served.
///
/// A per-request execution failure never kills the worker: the failed
/// request's response channel is dropped (so the client's `recv`
/// errors instead of hanging), the failure is counted, and serving
/// continues — otherwise one poisoned request could strand every
/// request still in the queue.
fn serve_loop<B: Backend>(shared: &Shared, max_batch: usize, mut backend: B) -> u64 {
    let mut served = 0u64;
    let mut batch: Vec<Request> = Vec::with_capacity(max_batch);
    loop {
        batch.clear();
        {
            let mut q = shared.queue.lock().unwrap();
            // `stop` is re-checked on every wakeup; because `shutdown`
            // publishes it under this mutex, the check-then-wait pair
            // cannot miss it.
            while q.is_empty() && !shared.stop.load(Ordering::SeqCst) {
                q = shared.cv.wait(q).unwrap();
            }
            if q.is_empty() && shared.stop.load(Ordering::SeqCst) {
                return served;
            }
            for _ in 0..max_batch {
                match q.pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
        }
        let t0 = Instant::now();
        let n = batch.len() as u32;
        for req in batch.drain(..) {
            let queue = req.enqueued.elapsed();
            match backend.infer(&req) {
                Ok(logits) => {
                    let exec = t0.elapsed() / n;
                    shared.served.fetch_add(1, Ordering::Relaxed);
                    served += 1;
                    // client may have gone away; that's fine
                    let _ = req.resp.send(Response {
                        id: req.id,
                        logits,
                        model: req.model.as_ref().map(|m| m.stamp()),
                        queue,
                        exec,
                    });
                }
                Err(e) => {
                    shared.failed.fetch_add(1, Ordering::Relaxed);
                    eprintln!("domino-serve: request {} failed: {e:#}", req.id);
                    // dropping req.resp closes the channel: the client
                    // unblocks with a recv error instead of hanging
                }
            }
        }
        backend.batch_done();
    }
}

/// Latency statistics helper for load tests.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// Percentile (0-100) by nearest-rank.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        if self.samples_us.is_empty() {
            return None;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        Some(v[rank.min(v.len() - 1)])
    }

    pub fn summary(&self) -> String {
        match (
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
        ) {
            (Some(p50), Some(p95), Some(p99)) => format!(
                "p50 {p50} us, p95 {p95} us, p99 {p99} us (n={})",
                self.count()
            ),
            _ => "no samples".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::refcompute::{forward, Tensor};
    use crate::model::{NetworkBuilder, TensorShape};
    use crate::testutil::Rng;

    /// A small conv net the sim backend can serve in well under a
    /// millisecond per image.
    fn small_net() -> Network {
        NetworkBuilder::new("serve-test", TensorShape::new(2, 6, 6))
            .conv(4, 3, 1, 1)
            .flatten()
            .fc_logits(5)
            .build()
    }

    #[test]
    fn latency_percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i));
        }
        assert_eq!(s.percentile(50.0), Some(51)); // nearest-rank on 1..=100
        assert_eq!(s.percentile(99.0), Some(99));
        assert_eq!(s.percentile(100.0), Some(100));
        assert_eq!(LatencyStats::default().percentile(50.0), None);
    }

    #[test]
    fn sim_backend_rejects_zero_workers() {
        let net = small_net();
        let (program, _) = sim_program(&net, ArchConfig::default()).unwrap();
        let bad = ServeConfig {
            workers: 0,
            ..Default::default()
        };
        assert!(Server::start_sim(bad, program).is_err());
    }

    #[test]
    fn sim_backend_roundtrip_matches_refcompute() {
        let net = small_net();
        let (program, weights) = sim_program(&net, ArchConfig::default()).unwrap();
        let server = Server::start_sim(
            ServeConfig {
                workers: 2,
                max_batch: 4,
                queue_cap: 64,
            },
            Arc::clone(&program),
        )
        .unwrap();
        assert_eq!(server.backend(), "sim");
        assert_eq!(server.input_len(), net.input_len());
        // wrong-size image rejected up front
        assert!(server.submit(vec![0i8; 3]).is_err());
        // responses are bit-exact vs the int8 reference, and stamped
        // with the (sole) model that served them
        let mut rng = Rng::new(77);
        for _ in 0..6 {
            let image = rng.i8_vec(net.input_len(), 31);
            let r = server.infer(image.clone()).unwrap();
            let want = forward(&net, &weights, &Tensor::new(net.input, image)).unwrap();
            assert_eq!(r.logits, want.data);
            let stamp = r.model.expect("sim responses carry a model stamp");
            assert_eq!(&*stamp.name, "serve-test");
            assert_eq!(stamp.version, 1);
        }
        assert_eq!(server.served(), 6);
        let counts = server.shutdown().unwrap();
        assert_eq!(counts.iter().sum::<u64>(), 6);
    }

    #[test]
    fn sim_backend_shutdown_under_load_answers_everything() {
        // Regression test for the missed-wakeup shutdown race: repeat
        // the submit-burst → immediate-shutdown cycle; with the old
        // unsynchronized `stop` store a worker could park forever and
        // `shutdown` would hang (the test would time out).
        let net = small_net();
        let (program, _) = sim_program(&net, ArchConfig::default()).unwrap();
        let mut rng = Rng::new(99);
        for round in 0..6 {
            let server = Server::start_sim(
                ServeConfig {
                    workers: 2,
                    max_batch: 3,
                    queue_cap: 128,
                },
                Arc::clone(&program),
            )
            .unwrap();
            let n = 4 + 3 * round as usize;
            let receivers: Vec<_> = (0..n)
                .map(|_| server.submit(rng.i8_vec(net.input_len(), 31)).unwrap())
                .collect();
            // shut down with the queue still loaded: workers must
            // drain it and answer every accepted request
            let counts = server.shutdown().unwrap();
            assert_eq!(counts.iter().sum::<u64>(), n as u64, "round {round}");
            for (i, rx) in receivers.into_iter().enumerate() {
                let r = rx.recv().expect("accepted request must be answered");
                assert_eq!(r.logits.len(), 5, "round {round} request {i}");
            }
        }
    }

    #[test]
    fn registry_load_swap_unload_lifecycle() {
        let registry = ModelRegistry::new();
        let net = small_net();
        let gen0 = registry.generation();
        let v1 = registry.load("alpha", &net, ArchConfig::default()).unwrap();
        assert!(registry.generation() > gen0, "load bumps the generation");
        assert_eq!(v1.version(), 1);
        assert_eq!(v1.name(), "alpha");
        assert_eq!(registry.names(), vec!["alpha".to_string()]);
        // duplicate load refused, pointing at swap
        let err = registry
            .load("alpha", &net, ArchConfig::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("swap"), "{err}");
        // swap of an unknown name lists what is loaded
        let err = registry
            .swap("nope", &net, ArchConfig::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("alpha"), "{err}");
        // swap bumps the version and mints a fresh id
        let v2 = registry.swap("alpha", &net, ArchConfig::default()).unwrap();
        assert_eq!(v2.version(), 2);
        assert_ne!(v2.id(), v1.id());
        // a seeded swap actually changes the weights
        let v3 = registry
            .swap_seeded("alpha", &net, ArchConfig::default(), Some(0xFEED))
            .unwrap();
        assert_eq!(v3.version(), 3);
        assert_ne!(
            v3.weights().unwrap().per_layer[0].as_slice(),
            v1.weights().unwrap().per_layer[0].as_slice(),
            "seeded swap must produce different weights"
        );
        // unload empties the registry; repeating it errors (and a
        // failed mutation leaves the generation alone)
        let gen_before = registry.generation();
        registry.unload("alpha").unwrap();
        assert!(registry.generation() > gen_before, "unload bumps the generation");
        assert!(registry.is_empty());
        let gen_after = registry.generation();
        assert!(registry.unload("alpha").is_err());
        assert_eq!(registry.generation(), gen_after);
        assert!(registry.get("alpha").is_none());
    }

    #[test]
    fn submit_requires_model_name_with_multiple_models() {
        let registry = Arc::new(ModelRegistry::new());
        let net = small_net();
        registry.load("a", &net, ArchConfig::default()).unwrap();
        registry.load("b", &net, ArchConfig::default()).unwrap();
        let server = Server::start_multi(
            ServeConfig {
                workers: 1,
                max_batch: 2,
                queue_cap: 16,
            },
            Arc::clone(&registry),
        )
        .unwrap();
        let img = vec![0i8; net.input_len()];
        let err = server.submit(img.clone()).unwrap_err().to_string();
        assert!(err.contains("submit_to"), "{err}");
        // named routing works for both
        assert_eq!(server.infer_on("a", img.clone()).unwrap().logits.len(), 5);
        assert_eq!(server.infer_on("b", img).unwrap().logits.len(), 5);
        // unknown model error lists the loaded names
        let err = server
            .submit_to("c", vec![0i8; net.input_len()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("[a, b]"), "{err}");
        server.shutdown().unwrap();
    }

    #[test]
    fn start_multi_rejects_empty_registry() {
        let registry = Arc::new(ModelRegistry::new());
        assert!(Server::start_multi(ServeConfig::default(), registry).is_err());
    }

    #[test]
    fn config_validation() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bad = ServeConfig {
            workers: 0,
            ..Default::default()
        };
        assert!(Server::start(bad).is_err());
    }

    #[test]
    fn serve_roundtrip_and_backpressure() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let server = Server::start(ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_cap: 8,
        })
        .unwrap();
        // wrong-size image rejected up front
        assert!(server.submit(vec![0i8; 3]).is_err());
        // correct request round-trips
        let r = server.infer(vec![1i8; 768]).unwrap();
        assert_eq!(r.logits.len(), 10);
        assert_eq!(server.served(), 1);
        // responses are deterministic
        let r2 = server.infer(vec![1i8; 768]).unwrap();
        assert_eq!(r.logits, r2.logits);
        let counts = server.shutdown().unwrap();
        assert_eq!(counts.iter().sum::<u64>(), 2);
    }
}
