//! Inference serving: the production-style request loop, with two
//! interchangeable execution backends behind one queue.
//!
//! The server is a bounded request queue with backpressure, a
//! configurable pool of worker threads, micro-batched dequeueing and
//! latency/throughput accounting (p50/p95/p99). What executes a
//! dequeued micro-batch is the **backend**:
//!
//! * **PJRT** ([`Server::start`]) — each worker owns a private PJRT
//!   client executing the AOT-compiled JAX/Pallas artifact (`make
//!   artifacts`; the `xla` crate's raw handles are not `Send`, hence
//!   per-worker clients). Python is never on this path.
//! * **Cycle simulator** ([`Server::start_sim`]) — each worker owns a
//!   [`crate::sim::Simulator`] over one shared compiled [`Program`]
//!   (the program is immutable and `Sync`; the per-tile runtime state
//!   lives in the worker's engine and is reset between images). This
//!   serves the paper's cycle-accurate datapath end-to-end —
//!   submit → micro-batch → response — and is what
//!   `benches/serve_sim_throughput.rs` load-tests. Build the shared
//!   program with [`sim_program`] so responses can be cross-checked
//!   bit-for-bit against `model::refcompute`.
//!
//! Shutdown is graceful under load: workers drain the queue completely
//! before exiting, so every accepted request is resolved — answered on
//! success, or its response channel closed on a per-request execution
//! failure (the client's `recv` errors instead of hanging; workers
//! keep serving and the failure is counted in [`Server::failed`]).
//! The `stop` flag is published while holding the queue mutex — a
//! store outside the lock could land between a worker's emptiness
//! check and its `Condvar::wait`, and the notification would be lost
//! (the classic missed-wakeup race; regression-tested below).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{ArchConfig, Compiler, Program};
use crate::model::refcompute::Weights;
use crate::model::Network;
use crate::sim::Simulator;

/// One inference request.
pub struct Request {
    pub id: u64,
    pub image: Vec<i8>,
    enqueued: Instant,
    resp: mpsc::Sender<Response>,
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<i8>,
    /// Time spent queued before a worker picked the request up.
    pub queue: Duration,
    /// Executor time (batch time attributed per request).
    pub exec: Duration,
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker threads (each with a private execution engine).
    pub workers: usize,
    /// Max requests drained per dequeue (micro-batch).
    pub max_batch: usize,
    /// Queue capacity; `submit` fails fast beyond it (backpressure).
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 8,
            queue_cap: 256,
        }
    }
}

#[derive(Default)]
struct Shared {
    queue: Mutex<VecDeque<Request>>,
    cv: Condvar,
    stop: AtomicBool,
    served: AtomicU64,
    rejected: AtomicU64,
    /// Requests whose execution failed (the client's channel is closed
    /// instead of answered; workers keep serving).
    failed: AtomicU64,
}

/// Which execution engine the workers build (internal; selected by the
/// `Server` constructor used).
enum BackendSpec {
    /// AOT artifact through a per-worker PJRT client.
    Pjrt,
    /// Cycle-accurate simulator over a shared compiled program.
    Sim(Arc<Program>),
}

/// Compile `net` for the cycle-simulator backend with the compiler's
/// deterministic weight seed. Returns the shared program and the exact
/// weights it bakes in, so callers can cross-check every response
/// against `model::refcompute::forward` bit-for-bit.
pub fn sim_program(net: &Network, arch: ArchConfig) -> Result<(Arc<Program>, Weights)> {
    let compiler = Compiler::new(arch);
    let weights = Weights::random(net, compiler.weight_seed)?;
    let program = compiler.compile_with_weights(net, &weights)?;
    Ok((Arc::new(program), weights))
}

/// A running inference server.
pub struct Server {
    shared: Arc<Shared>,
    cfg: ServeConfig,
    workers: Vec<std::thread::JoinHandle<Result<u64>>>,
    next_id: AtomicU64,
    input_len: usize,
    backend: &'static str,
}

impl Server {
    /// Start `cfg.workers` threads serving the trained tiny-cnn
    /// artifact over PJRT. Fails immediately if the artifacts are
    /// missing.
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        if !crate::runtime::artifacts_available() {
            bail!("artifacts not built (run `make artifacts`)");
        }
        Self::start_backend(cfg, BackendSpec::Pjrt, 3 * 16 * 16, "pjrt")
    }

    /// Start `cfg.workers` threads serving the cycle-accurate simulator
    /// over a shared compiled program (see [`sim_program`]). Needs no
    /// artifacts: the whole datapath is the Rust engine.
    pub fn start_sim(cfg: ServeConfig, program: Arc<Program>) -> Result<Self> {
        let input_len = program.net.input_len();
        Self::start_backend(cfg, BackendSpec::Sim(program), input_len, "sim")
    }

    fn start_backend(
        cfg: ServeConfig,
        spec: BackendSpec,
        input_len: usize,
        backend: &'static str,
    ) -> Result<Self> {
        anyhow::ensure!(cfg.workers >= 1 && cfg.max_batch >= 1);
        let shared = Arc::new(Shared::default());
        let mut workers = Vec::with_capacity(cfg.workers);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let ready = ready_tx.clone();
            let max_batch = cfg.max_batch;
            let spec = match &spec {
                BackendSpec::Pjrt => BackendSpec::Pjrt,
                BackendSpec::Sim(p) => BackendSpec::Sim(Arc::clone(p)),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("domino-worker-{w}"))
                    .spawn(move || worker_entry(shared, max_batch, spec, ready))
                    .context("spawn worker")?,
            );
        }
        drop(ready_tx);
        // wait until every worker has built its execution engine
        for _ in 0..cfg.workers {
            ready_rx
                .recv()
                .context("worker died during startup")??;
        }
        Ok(Self {
            shared,
            cfg,
            workers,
            next_id: AtomicU64::new(0),
            input_len,
            backend,
        })
    }

    /// Flat input length this server accepts (backend model's input).
    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Which backend the workers run (`"pjrt"` or `"sim"`).
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// Submit one image; returns a receiver for the response. Fails
    /// fast when the queue is full (backpressure) or the image is the
    /// wrong size.
    pub fn submit(&self, image: Vec<i8>) -> Result<mpsc::Receiver<Response>> {
        if image.len() != self.input_len {
            bail!(
                "image must be {} int8 values (got {})",
                self.input_len,
                image.len()
            );
        }
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.cfg.queue_cap {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                bail!("queue full ({}): backpressure", self.cfg.queue_cap);
            }
            q.push_back(Request {
                id,
                image,
                enqueued: Instant::now(),
                resp: tx,
            });
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Synchronous convenience: submit + wait.
    pub fn infer(&self, image: Vec<i8>) -> Result<Response> {
        let rx = self.submit(image)?;
        rx.recv().context("worker dropped the request")
    }

    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Requests whose execution failed after being accepted. Each one
    /// had its response channel closed (the client's `recv` errors)
    /// rather than hanging; the worker that hit the failure keeps
    /// serving.
    pub fn failed(&self) -> u64 {
        self.shared.failed.load(Ordering::Relaxed)
    }

    /// Stop workers and join them; returns per-worker served counts.
    ///
    /// Workers drain the queue before exiting, so every request
    /// accepted by `submit` before this call is still resolved —
    /// answered, or its channel closed if its execution failed.
    pub fn shutdown(mut self) -> Result<Vec<u64>> {
        {
            // Publish `stop` while holding the queue mutex: a worker is
            // either before its predicate check (it will see the flag)
            // or already parked in `wait` (it will see the notify).
            // Storing without the lock could slot between a worker's
            // check and its wait, losing the wakeup forever.
            let _q = self.shared.queue.lock().unwrap();
            self.shared.stop.store(true, Ordering::SeqCst);
        }
        self.shared.cv.notify_all();
        let mut counts = Vec::new();
        for w in self.workers.drain(..) {
            counts.push(w.join().map_err(|_| anyhow::anyhow!("worker panicked"))??);
        }
        Ok(counts)
    }
}

/// Worker thread entry: build the backend's execution engine, signal
/// readiness, then serve micro-batches until shutdown.
fn worker_entry(
    shared: Arc<Shared>,
    max_batch: usize,
    spec: BackendSpec,
    ready: mpsc::Sender<Result<()>>,
) -> Result<u64> {
    match spec {
        BackendSpec::Pjrt => {
            // each worker owns a full PJRT stack (handles are not Send)
            let init = (|| -> Result<crate::runtime::golden::TrainedTiny> {
                let rt = crate::runtime::Runtime::cpu()?;
                crate::runtime::golden::TrainedTiny::load(&rt)
            })();
            let exe = match init {
                Ok(e) => {
                    let _ = ready.send(Ok(()));
                    e
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    let _ = ready.send(Err(e));
                    bail!("worker init failed: {msg}");
                }
            };
            Ok(serve_loop(&shared, max_batch, |img| exe.run(img)))
        }
        BackendSpec::Sim(program) => {
            // per-worker engine over the shared immutable program; the
            // engine's per-tile state is built once here and reset
            // between images.
            let mut sim = Simulator::new(&program);
            let _ = ready.send(Ok(()));
            Ok(serve_loop(&shared, max_batch, move |img| {
                sim.run_image(img).map(|out| out.scores)
            }))
        }
    }
}

/// The backend-agnostic micro-batch loop: block until work or stop,
/// drain up to `max_batch` requests, execute, respond. Returns the
/// number of requests this worker served.
///
/// A per-request execution failure never kills the worker: the failed
/// request's response channel is dropped (so the client's `recv`
/// errors instead of hanging), the failure is counted, and serving
/// continues — otherwise one poisoned request could strand every
/// request still in the queue.
fn serve_loop<F>(shared: &Shared, max_batch: usize, mut infer: F) -> u64
where
    F: FnMut(&[i8]) -> Result<Vec<i8>>,
{
    let mut served = 0u64;
    let mut batch: Vec<Request> = Vec::with_capacity(max_batch);
    loop {
        batch.clear();
        {
            let mut q = shared.queue.lock().unwrap();
            // `stop` is re-checked on every wakeup; because `shutdown`
            // publishes it under this mutex, the check-then-wait pair
            // cannot miss it.
            while q.is_empty() && !shared.stop.load(Ordering::SeqCst) {
                q = shared.cv.wait(q).unwrap();
            }
            if q.is_empty() && shared.stop.load(Ordering::SeqCst) {
                return served;
            }
            for _ in 0..max_batch {
                match q.pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
        }
        let t0 = Instant::now();
        let n = batch.len() as u32;
        for req in batch.drain(..) {
            let queue = req.enqueued.elapsed();
            match infer(&req.image) {
                Ok(logits) => {
                    let exec = t0.elapsed() / n;
                    shared.served.fetch_add(1, Ordering::Relaxed);
                    served += 1;
                    // client may have gone away; that's fine
                    let _ = req.resp.send(Response {
                        id: req.id,
                        logits,
                        queue,
                        exec,
                    });
                }
                Err(e) => {
                    shared.failed.fetch_add(1, Ordering::Relaxed);
                    eprintln!("domino-serve: request {} failed: {e:#}", req.id);
                    // dropping req.resp closes the channel: the client
                    // unblocks with a recv error instead of hanging
                }
            }
        }
    }
}

/// Latency statistics helper for load tests.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// Percentile (0-100) by nearest-rank.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        if self.samples_us.is_empty() {
            return None;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        Some(v[rank.min(v.len() - 1)])
    }

    pub fn summary(&self) -> String {
        match (
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
        ) {
            (Some(p50), Some(p95), Some(p99)) => format!(
                "p50 {p50} us, p95 {p95} us, p99 {p99} us (n={})",
                self.count()
            ),
            _ => "no samples".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::refcompute::{forward, Tensor};
    use crate::model::{NetworkBuilder, TensorShape};
    use crate::testutil::Rng;

    /// A small conv net the sim backend can serve in well under a
    /// millisecond per image.
    fn small_net() -> Network {
        NetworkBuilder::new("serve-test", TensorShape::new(2, 6, 6))
            .conv(4, 3, 1, 1)
            .flatten()
            .fc_logits(5)
            .build()
    }

    #[test]
    fn latency_percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i));
        }
        assert_eq!(s.percentile(50.0), Some(51)); // nearest-rank on 1..=100
        assert_eq!(s.percentile(99.0), Some(99));
        assert_eq!(s.percentile(100.0), Some(100));
        assert_eq!(LatencyStats::default().percentile(50.0), None);
    }

    #[test]
    fn sim_backend_rejects_zero_workers() {
        let net = small_net();
        let (program, _) = sim_program(&net, ArchConfig::default()).unwrap();
        let bad = ServeConfig {
            workers: 0,
            ..Default::default()
        };
        assert!(Server::start_sim(bad, program).is_err());
    }

    #[test]
    fn sim_backend_roundtrip_matches_refcompute() {
        let net = small_net();
        let (program, weights) = sim_program(&net, ArchConfig::default()).unwrap();
        let server = Server::start_sim(
            ServeConfig {
                workers: 2,
                max_batch: 4,
                queue_cap: 64,
            },
            Arc::clone(&program),
        )
        .unwrap();
        assert_eq!(server.backend(), "sim");
        assert_eq!(server.input_len(), net.input_len());
        // wrong-size image rejected up front
        assert!(server.submit(vec![0i8; 3]).is_err());
        // responses are bit-exact vs the int8 reference
        let mut rng = Rng::new(77);
        for _ in 0..6 {
            let image = rng.i8_vec(net.input_len(), 31);
            let r = server.infer(image.clone()).unwrap();
            let want = forward(&net, &weights, &Tensor::new(net.input, image)).unwrap();
            assert_eq!(r.logits, want.data);
        }
        assert_eq!(server.served(), 6);
        let counts = server.shutdown().unwrap();
        assert_eq!(counts.iter().sum::<u64>(), 6);
    }

    #[test]
    fn sim_backend_shutdown_under_load_answers_everything() {
        // Regression test for the missed-wakeup shutdown race: repeat
        // the submit-burst → immediate-shutdown cycle; with the old
        // unsynchronized `stop` store a worker could park forever and
        // `shutdown` would hang (the test would time out).
        let net = small_net();
        let (program, _) = sim_program(&net, ArchConfig::default()).unwrap();
        let mut rng = Rng::new(99);
        for round in 0..6 {
            let server = Server::start_sim(
                ServeConfig {
                    workers: 2,
                    max_batch: 3,
                    queue_cap: 128,
                },
                Arc::clone(&program),
            )
            .unwrap();
            let n = 4 + 3 * round as usize;
            let receivers: Vec<_> = (0..n)
                .map(|_| server.submit(rng.i8_vec(net.input_len(), 31)).unwrap())
                .collect();
            // shut down with the queue still loaded: workers must
            // drain it and answer every accepted request
            let counts = server.shutdown().unwrap();
            assert_eq!(counts.iter().sum::<u64>(), n as u64, "round {round}");
            for (i, rx) in receivers.into_iter().enumerate() {
                let r = rx.recv().expect("accepted request must be answered");
                assert_eq!(r.logits.len(), 5, "round {round} request {i}");
            }
        }
    }

    #[test]
    fn config_validation() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bad = ServeConfig {
            workers: 0,
            ..Default::default()
        };
        assert!(Server::start(bad).is_err());
    }

    #[test]
    fn serve_roundtrip_and_backpressure() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let server = Server::start(ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_cap: 8,
        })
        .unwrap();
        // wrong-size image rejected up front
        assert!(server.submit(vec![0i8; 3]).is_err());
        // correct request round-trips
        let r = server.infer(vec![1i8; 768]).unwrap();
        assert_eq!(r.logits.len(), 10);
        assert_eq!(server.served(), 1);
        // responses are deterministic
        let r2 = server.infer(vec![1i8; 768]).unwrap();
        assert_eq!(r.logits, r2.logits);
        let counts = server.shutdown().unwrap();
        assert_eq!(counts.iter().sum::<u64>(), 2);
    }
}
