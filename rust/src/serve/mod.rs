//! Inference serving: the production-style request loop, the
//! multi-model registry with hot-swap — and, around them, one typed
//! service API that local callers and remote clients share.
//!
//! ## Layout
//!
//! * [`server`](self) core ([`Server`], [`ServeConfig`]) — a bounded
//!   request queue with backpressure, a configurable worker pool,
//!   micro-batched dequeueing and graceful drain-on-shutdown, over two
//!   interchangeable execution backends: the AOT artifact through
//!   PJRT ([`Server::start`]) and the cycle-accurate simulator
//!   ([`Server::start_sim`], [`Server::start_multi`]).
//! * [`ModelRegistry`] / [`ModelVersion`] — versioned compiled
//!   programs, load/hot-swap/unload safe under traffic; every response
//!   is stamped ([`ModelStamp`]) with the exact version that served it
//!   so callers can cross-check bit-for-bit against
//!   [`ModelVersion::refcompute`]. A request resolves its version at
//!   **submit** time and carries the `Arc` through the queue, so
//!   swap/unload never drop or reroute in-flight work.
//! * [`api`] — the typed service surface: `Request`/`Response` enums
//!   covering the data plane (`Infer`), the admin plane
//!   (`Load`/`LoadSeeded`/`Swap`/`Unload`) and the observability plane
//!   (`ListModels`/`ModelInfo`/`Stats`) and the fault plane
//!   (`FaultInject` arms a deterministic [`crate::sim::FaultPlan`] on
//!   a model, `Canary` runs a seeded sentinel inference against the
//!   refcompute oracle and, with `heal`, re-maps the model around the
//!   armed fault sites) — all executed by one [`Service::dispatch`] —
//!   the in-process path and the network path are the same call.
//!   [`api::RegistryManifest`] persists the loaded set across
//!   restarts (`serve --registry-file`).
//! * [`wire`] — the dependency-free wire protocol: length-prefixed
//!   frames of hand-rolled, escaping-correct JSON (std only; the
//!   build image is offline, so no serde).
//! * [`net`] — the TCP endpoint (`domino serve --listen ADDR`): a
//!   nonblocking poll loop (one event thread owns accept + every
//!   connection's reads and writes; a dispatcher pool executes
//!   requests), protocol-v2 request ids for many-in-flight pipelined
//!   connections, bounded connection count, graceful drain on
//!   shutdown. It serves any [`api::Dispatcher`] — a leaf [`Service`]
//!   or a cluster [`cluster::Router`].
//! * [`cluster`] — the cluster plane (`domino cluster …`): a
//!   [`cluster::Router`] sharding models over many serve processes by
//!   rendezvous hashing with replication, least-loaded dispatch among
//!   replicas, health probing, and drain-aware failover that re-loads
//!   models from the router's recorded (zoo, seed, mapping) specs.
//!   The health thread also runs per-model canary inferences, so a
//!   backend that answers the socket but serves silently-wrong bits
//!   (a faulty tile) is excluded from routing exactly like a dead
//!   one — `cluster status` tells the two states apart.
//! * [`client`] — the in-crate typed client (`domino client …`, the
//!   benches and the protocol smoke test); synchronous calls plus a
//!   pipelined submit/await-by-id mode over one connection.
//! * [`metrics`] — per-model observability: p50/p95/p99 latency,
//!   served/failed/rejected counts and live queue-depth gauges, keyed
//!   by model name and served through the `Stats` request.
//! * [`traffic`] — serving under hostile reality: a request
//!   record/replay plane (timestamped, model-tagged logs captured off
//!   [`Service::dispatch`], replayed at 1x/max/scaled speed with
//!   byte-identical-response checking) and the scenario harness
//!   (Poisson/bursty open-loop arrivals, overload past `queue_cap`
//!   with typed-rejection accounting, admin+data storms, slow-loris
//!   clients, SLO-conditioned load search) behind
//!   `domino traffic record|replay|scenario`.
//!
//! ## Hot-swap semantics
//!
//! [`ModelRegistry::swap`] compiles the replacement *outside* the
//! registry lock, then atomically republishes the name: requests
//! accepted against the old version drain on the old program,
//! requests submitted after the swap run on the new one. Workers keep
//! one warm [`crate::sim::PooledEngine`] per loaded model in a
//! [`crate::sim::EnginePool`] and prune engines of dead versions after
//! a micro-batch.
//!
//! Shutdown is graceful under load: workers drain the queue completely
//! before exiting, so every accepted request is resolved — answered on
//! success, or its response channel closed on a per-request execution
//! failure (the client's `recv` errors instead of hanging; workers
//! keep serving and the failure is counted in [`Server::failed`]).
//! The `stop` flag is published while holding the queue mutex — a
//! store outside the lock could land between a worker's emptiness
//! check and its `Condvar::wait`, and the notification would be lost
//! (the classic missed-wakeup race; regression-tested in `server`).

pub mod api;
pub mod client;
pub mod cluster;
pub mod metrics;
pub mod net;
mod registry;
mod server;
pub mod traffic;
pub mod wire;

pub use api::{Dispatcher, Service};
pub use cluster::{ClusterConfig, Router};
pub use metrics::{LatencyStats, ModelMetricsSnapshot};
pub use registry::{sim_program, ModelRegistry, ModelStamp, ModelVersion};
pub use server::{Request, Response, ServeConfig, Server};
