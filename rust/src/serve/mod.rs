//! Inference serving: the L3 request loop over the AOT artifact.
//!
//! After `make artifacts` the trained network is a self-contained HLO
//! executable; this module serves it like a production endpoint:
//! bounded request queue with backpressure, a configurable pool of
//! worker threads (each owning its own PJRT client — the `xla` crate's
//! raw handles are not `Send`), micro-batched dequeueing, and latency/
//! throughput accounting (p50/p95/p99).
//!
//! Python is *never* on this path: workers execute the compiled
//! artifact directly. The `serve_throughput` example drives a closed-
//! loop load test over the held-out test set and cross-checks every
//! response against the Rust int8 reference.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// One inference request.
pub struct Request {
    pub id: u64,
    pub image: Vec<i8>,
    enqueued: Instant,
    resp: mpsc::Sender<Response>,
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<i8>,
    /// Time spent queued before a worker picked the request up.
    pub queue: Duration,
    /// Executor time (batch time attributed per request).
    pub exec: Duration,
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker threads (each with a private PJRT client + executable).
    pub workers: usize,
    /// Max requests drained per dequeue (micro-batch).
    pub max_batch: usize,
    /// Queue capacity; `submit` fails fast beyond it (backpressure).
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 8,
            queue_cap: 256,
        }
    }
}

#[derive(Default)]
struct Shared {
    queue: Mutex<VecDeque<Request>>,
    cv: Condvar,
    stop: AtomicBool,
    served: AtomicU64,
    rejected: AtomicU64,
}

/// A running inference server.
pub struct Server {
    shared: Arc<Shared>,
    cfg: ServeConfig,
    workers: Vec<std::thread::JoinHandle<Result<u64>>>,
    next_id: AtomicU64,
}

impl Server {
    /// Start `cfg.workers` threads serving the trained tiny-cnn
    /// artifact. Fails immediately if the artifacts are missing.
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        if !crate::runtime::artifacts_available() {
            bail!("artifacts not built (run `make artifacts`)");
        }
        anyhow::ensure!(cfg.workers >= 1 && cfg.max_batch >= 1);
        let shared = Arc::new(Shared::default());
        let mut workers = Vec::with_capacity(cfg.workers);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let ready = ready_tx.clone();
            let max_batch = cfg.max_batch;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("domino-worker-{w}"))
                    .spawn(move || worker_loop(shared, max_batch, ready))
                    .context("spawn worker")?,
            );
        }
        drop(ready_tx);
        // wait until every worker has compiled its executable
        for _ in 0..cfg.workers {
            ready_rx
                .recv()
                .context("worker died during startup")??;
        }
        Ok(Self {
            shared,
            cfg,
            workers,
            next_id: AtomicU64::new(0),
        })
    }

    /// Submit one image; returns a receiver for the response. Fails
    /// fast when the queue is full (backpressure) or the image is the
    /// wrong size.
    pub fn submit(&self, image: Vec<i8>) -> Result<mpsc::Receiver<Response>> {
        if image.len() != 3 * 16 * 16 {
            bail!("image must be 3x16x16 int8");
        }
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.cfg.queue_cap {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                bail!("queue full ({}): backpressure", self.cfg.queue_cap);
            }
            q.push_back(Request {
                id,
                image,
                enqueued: Instant::now(),
                resp: tx,
            });
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Synchronous convenience: submit + wait.
    pub fn infer(&self, image: Vec<i8>) -> Result<Response> {
        let rx = self.submit(image)?;
        rx.recv().context("worker dropped the request")
    }

    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Stop workers and join them; returns per-worker served counts.
    pub fn shutdown(mut self) -> Result<Vec<u64>> {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        let mut counts = Vec::new();
        for w in self.workers.drain(..) {
            counts.push(w.join().map_err(|_| anyhow::anyhow!("worker panicked"))??);
        }
        Ok(counts)
    }
}

fn worker_loop(
    shared: Arc<Shared>,
    max_batch: usize,
    ready: mpsc::Sender<Result<()>>,
) -> Result<u64> {
    // each worker owns a full PJRT stack (handles are not Send)
    let init = (|| -> Result<crate::runtime::golden::TrainedTiny> {
        let rt = crate::runtime::Runtime::cpu()?;
        crate::runtime::golden::TrainedTiny::load(&rt)
    })();
    let exe = match init {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let _ = ready.send(Err(e));
            bail!("worker init failed: {msg}");
        }
    };

    let mut served = 0u64;
    let mut batch: Vec<Request> = Vec::with_capacity(max_batch);
    loop {
        batch.clear();
        {
            let mut q = shared.queue.lock().unwrap();
            while q.is_empty() && !shared.stop.load(Ordering::SeqCst) {
                q = shared.cv.wait(q).unwrap();
            }
            if q.is_empty() && shared.stop.load(Ordering::SeqCst) {
                return Ok(served);
            }
            for _ in 0..max_batch {
                match q.pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
        }
        let t0 = Instant::now();
        let n = batch.len() as u32;
        for req in batch.drain(..) {
            let queue = req.enqueued.elapsed();
            let logits = exe.run(&req.image)?;
            let exec = t0.elapsed() / n;
            shared.served.fetch_add(1, Ordering::Relaxed);
            served += 1;
            // client may have gone away; that's fine
            let _ = req.resp.send(Response {
                id: req.id,
                logits,
                queue,
                exec,
            });
        }
    }
}

/// Latency statistics helper for load tests.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// Percentile (0-100) by nearest-rank.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        if self.samples_us.is_empty() {
            return None;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        Some(v[rank.min(v.len() - 1)])
    }

    pub fn summary(&self) -> String {
        match (
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
        ) {
            (Some(p50), Some(p95), Some(p99)) => format!(
                "p50 {p50} us, p95 {p95} us, p99 {p99} us (n={})",
                self.count()
            ),
            _ => "no samples".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i));
        }
        assert_eq!(s.percentile(50.0), Some(51)); // nearest-rank on 1..=100
        assert_eq!(s.percentile(99.0), Some(99));
        assert_eq!(s.percentile(100.0), Some(100));
        assert_eq!(LatencyStats::default().percentile(50.0), None);
    }

    #[test]
    fn config_validation() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bad = ServeConfig {
            workers: 0,
            ..Default::default()
        };
        assert!(Server::start(bad).is_err());
    }

    #[test]
    fn serve_roundtrip_and_backpressure() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let server = Server::start(ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_cap: 8,
        })
        .unwrap();
        // wrong-size image rejected up front
        assert!(server.submit(vec![0i8; 3]).is_err());
        // correct request round-trips
        let r = server.infer(vec![1i8; 768]).unwrap();
        assert_eq!(r.logits.len(), 10);
        assert_eq!(server.served(), 1);
        // responses are deterministic
        let r2 = server.infer(vec![1i8; 768]).unwrap();
        assert_eq!(r.logits, r2.logits);
        let counts = server.shutdown().unwrap();
        assert_eq!(counts.iter().sum::<u64>(), 2);
    }
}
