//! Hand-rolled CLI (no network access in this environment, so no clap;
//! the parser is ~60 lines and fully tested).

use std::collections::HashMap;

/// Parsed command line: subcommand, positional args, `--key value` /
/// `--flag` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
}

impl Args {
    /// Parse from raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut it = raw.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut positional = Vec::new();
        let mut options = HashMap::new();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let takes_value = it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if takes_value {
                    options.insert(key.to_string(), it.next().unwrap());
                } else {
                    options.insert(key.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Self {
            command,
            positional,
            options,
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.options.get(key).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

pub const USAGE: &str = "\
domino — Computing-On-the-Move NoC/CIM accelerator (paper reproduction)

USAGE: domino <COMMAND> [OPTIONS]

Any model-taking command also accepts --config <file> ([arch]/[run]
sections, see rust/src/config.rs).

COMMANDS:
  table4                 regenerate Table IV (all five comparisons)
  breakdown              power breakdown (Section IV-B-3)
  accuracy [--limit N]   quantization-accuracy experiment (needs artifacts)
  map <model> [--chips N]      compile a model; print the tile mapping
  map explore <model> [--objective latency|energy|tiles] [--top N]
        [--verify] [--load-into HOST:PORT]
                         rank candidate mappings (pooling x placement x
                         mesh shape x chip alignment) by analytic cost
                         (perfmodel timing, Table III energy, worst-link
                         NoC load); --verify compiles the winner and
                         serves one refcompute-checked inference,
                         --load-into feeds the winner straight into a
                         running `serve --listen` endpoint
  run <model> [--images N] [--seed S] [--chips N] [--threads T]
                         cycle-simulate images; print stats + energy
                         (--threads > 1 uses the batched parallel path)
  trace [--stage I]      print the Fig. 3(b) COM dataflow trace
  debug <model> [--seed S] [--break tile,cycle[,kind][;spec...]]
        [--steps N] [--heatmap] [--stage I] [--buckets N]
                         flight-recorder debug stepper: record one
                         seeded image, stop at breakpoints (`*` is a
                         wildcard; kinds: acc push pop emit link enter
                         exit fifo arena), single-step N events, and
                         inspect engine state (stage, FIFO depths, psum
                         arenas, link bits); --heatmap renders link
                         utilization over time for --stage (default:
                         the busiest stage). A breakpoint that never
                         hits exits 0 (the stream just ends)
  pipeline <model> [--images N] [--chips N]
                         steady-state layer-synchronized pipeline timing
  ablate                 dataflow (A1) + pooling (Fig. 4) ablations
  sweep [--models a,b]   mapping explorer across crossbar sizes
  golden [--images N]    check AOT golden model vs reference (needs artifacts)
  serve [--backend pjrt|sim] [--model M | --models a,b,c] [--workers N]
        [--batch B] [--requests R] [--queue Q] [--dispatchers D] [--seed S]
        [--swap M [--swap-after K]]
        [--listen ADDR [--serve-secs N]] [--registry-file PATH]
                         run the inference server: `pjrt` serves the AOT
                         artifact over the test set (needs artifacts);
                         `sim` serves the cycle-accurate simulator —
                         `--models` loads several models into one server
                         and routes tagged requests, `--swap` hot-swaps
                         a model (fresh weights) mid-traffic after K
                         requests; every response is cross-checked vs
                         refcompute for the exact model version that
                         served it. `--listen HOST:PORT` (sim only)
                         exposes the typed service API over TCP instead
                         (port 0 picks an ephemeral port and prints the
                         bound address); `--dispatchers` sizes the TCP
                         endpoint's dispatcher thread pool (default 4,
                         0 is rejected with a typed error);
                         `--registry-file` persists the loaded-model
                         set across restarts
  client <op> --addr HOST:PORT [--json]
                         drive a `serve --listen` endpoint: infer <m>
                         [--requests N] [--seed S] [--verify-seed S],
                         load <m> [--seed S] [--pooling P] [--placement P]
                         [--mesh-cols N] [--chip-aligned [true|false]]
                         [--sync-chips N]
                         (per-model mapping; defaults to the server's),
                         swap <m> [--seed S] (keeps the model's mapping),
                         unload <m>, models, info <m> (incl. mapping +
                         placement stats), stats,
                         trace <m> [--seed S] [--window N] (pull a
                         flight recording + link heatmap off the live
                         endpoint)
  traffic record --out FILE [--models a,b,c] [--requests N] [--seed S]
          [--rate R | --burst B --gap-us G]
                         capture a timestamped, model-tagged request log:
                         starts a sim service over --models (default
                         tiny-mlp,tiny-cnn), drives N open-loop requests
                         at the given arrival process through it with a
                         recorder tapped on dispatch, writes the
                         versioned framed log to FILE
  traffic replay FILE [--speed 1x|max|Nx|N/Mx] [--addr HOST:PORT]
          [--admission live|recorded]
                         re-issue a recorded log at the given speed
                         (default max): against a fresh local service
                         built from the log's own load requests, or
                         against a live endpoint via --addr; every
                         comparable response is checked byte-for-byte
                         against the recording (timing fields excluded,
                         point-in-time stats skipped) and the first
                         divergence is printed. Exits non-zero on any
                         mismatch. --admission recorded re-applies the
                         recorded accept/reject decisions, so logs
                         containing backpressure rejections replay
                         byte-identically at any speed
  traffic scenario [--smoke] [--models a,b,c] [--seed S] [--out FILE]
                         hostile-reality scenario suite on a deliberately
                         small service (2 workers, queue_cap 8): overload
                         past queue_cap (typed rejections only, zero
                         drops), bursty open-loop arrivals, mixed
                         admin+data storm (hot-swap/load under flood),
                         slow-loris TCP client vs well-behaved peer, and
                         an SLO-conditioned load search (max rate at
                         p99 < 200ms). Violated invariants exit non-zero;
                         --out writes the wire-JSON report (the serve
                         bench embeds the same shape into BENCH_serve.json)
  cluster serve (--spawn N | --backends a,b,c) --listen ADDR
          [--models a,b,c] [--replication R] [--seed S]
          [--workers N] [--dispatchers D] [--serve-secs N]
                         run a cluster router: shard + replicate models
                         over N spawned backend processes (or attach to
                         already-running --backends), health-check them,
                         fail over on backend death, and serve the same
                         typed API on --listen. Models are assigned by
                         rendezvous hashing with --replication copies
                         (default 2) and least-loaded dispatch among
                         replicas. NOTE: the wire protocol is plaintext
                         and unauthenticated — bind routers and backends
                         to trusted networks only
  cluster status --backends a,b,c [--models a,b,c]
                         probe each backend once and print liveness,
                         loaded models, and the model->owner assignments
                         the router would use; a canary inference per
                         discovered model distinguishes `canary-failed`
                         (socket answers, outputs silently wrong) from
                         `DEAD` (socket down)
  fault inject <model> --plan SPEC [--addr HOST:PORT] [--heal]
        [--seed S] [--canary-seed S]
                         arm a deterministic fault plan (SPEC: `;`-joined
                         sites — tile:<chip>:<r>:<c>:stuck:<v>|dead,
                         link:<chip>:<r>:<c>:flip:<bit>|drop, optional
                         @from-to slot window; empty SPEC disarms) on a
                         live endpoint (--addr) or a local one-shot
                         service, and print the seeded diagnostic report
                         (fires, corrupted lanes, outputs wrong vs
                         refcompute); --heal follows with a healing
                         canary that re-maps around the fault sites
  fault canary <model> [--heal] [--addr HOST:PORT] [--canary-seed S]
                         one seeded sentinel inference checked
                         bit-for-bit against the refcompute oracle —
                         the detector for silent corruption; --heal
                         re-maps around armed fault sites on failure
  fault storm [--models a,b,c] [--seed S]
                         end-to-end drill: per model, arm a stuck-at
                         tile fault, prove the corruption is silent,
                         detect + heal via canary, report recovery
                         times; exits non-zero if anything stays
                         corrupt (default models: the tiny trio)
  models [list|info <m>] [--json]
                         list zoo models (params/MACs/shapes), or show
                         one model in detail incl. its mapping stats at
                         the default (or --config/--chips) arch; --json
                         emits the wire-protocol ModelDesc representation

Model names are case-insensitive; `_` and `-` are interchangeable.
Models: vgg11-cifar10 resnet18-cifar10 vgg16-imagenet vgg19-imagenet
        resnet18-imagenet tiny-cnn tiny-mlp tiny-resnet
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_and_positional() {
        let a = parse("run tiny-cnn");
        assert_eq!(a.command, "run");
        assert_eq!(a.positional, vec!["tiny-cnn"]);
    }

    #[test]
    fn parses_options_and_flags() {
        let a = parse("run tiny --images 5 --verbose --seed 42");
        assert_eq!(a.get_usize("images", 1), 5);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_u64("seed", 0), 42);
    }

    #[test]
    fn missing_values_default() {
        let a = parse("table4");
        assert_eq!(a.get_usize("images", 3), 3);
        assert!(!a.flag("nope"));
    }

    #[test]
    fn empty_args() {
        let a = Args::parse(Vec::<String>::new());
        assert_eq!(a.command, "");
    }
}
