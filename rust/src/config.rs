//! Configuration files: a small INI/TOML-subset parser (offline
//! environment — no serde/toml crates) covering everything the CLI can
//! set, so experiments are reproducible from a checked-in file:
//!
//! ```text
//! # comment
//! [arch]
//! n_c = 256
//! n_m = 256
//! tiles_per_chip = 240
//! mesh_cols = 16
//! pooling = "block-reuse"      # or "weight-duplication"
//! placement = "serpentine"     # or "column-major"
//! chip_aligned = false         # pad chains to chip boundaries
//! sync_chips = 5               # omit to disable water-filling
//!
//! [run]
//! model = "vgg11-cifar10"
//! images = 4
//! seed = 42
//! ```
//!
//! `domino run --config exp.toml` (any subcommand accepting a model)
//! applies `[arch]`, and `[run]` supplies defaults for the run options.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::{ArchConfig, Placement, PoolingScheme};

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Bool(bool),
    Str(String),
}

impl Value {
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed config: `section.key -> value`.
#[derive(Clone, Debug, Default)]
pub struct Config {
    entries: BTreeMap<(String, String), Value>,
}

impl Config {
    /// Parse the INI/TOML subset (sections, `key = value`, `#`/`;`
    /// comments, quoted strings, integers, booleans).
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split(['#', ';']).next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let Some(name) = name.strip_suffix(']') else {
                    bail!("line {}: unterminated section header", ln + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`, got {line:?}", ln + 1);
            };
            let key = k.trim().to_string();
            if key.is_empty() {
                bail!("line {}: empty key", ln + 1);
            }
            let v = v.trim();
            let value = if let Some(q) = v.strip_prefix('"') {
                let Some(q) = q.strip_suffix('"') else {
                    bail!("line {}: unterminated string", ln + 1);
                };
                Value::Str(q.to_string())
            } else if v == "true" || v == "false" {
                Value::Bool(v == "true")
            } else if let Ok(i) = v.parse::<i64>() {
                Value::Int(i)
            } else {
                // bare word = string (toml would reject; we are lenient)
                Value::Str(v.to_string())
            };
            entries.insert((section.clone(), key), value);
        }
        Ok(Self { entries })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parse {}", path.display()))
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries
            .get(&(section.to_string(), key.to_string()))
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        self.get(section, key).and_then(Value::as_usize)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key).and_then(Value::as_str)
    }

    /// Build an [`ArchConfig`] from `[arch]`, starting from defaults.
    pub fn arch(&self) -> Result<ArchConfig> {
        let mut a = ArchConfig::default();
        if let Some(v) = self.get_usize("arch", "n_c") {
            a.n_c = v;
        }
        if let Some(v) = self.get_usize("arch", "n_m") {
            a.n_m = v;
        }
        if let Some(v) = self.get_usize("arch", "tiles_per_chip") {
            a.tiles_per_chip = v;
        }
        if let Some(v) = self.get_usize("arch", "mesh_cols") {
            a.mesh_cols = v;
        }
        if let Some(p) = self.get_str("arch", "pooling") {
            a.pooling = PoolingScheme::parse(p).context("[arch] pooling")?;
        }
        if let Some(p) = self.get_str("arch", "placement") {
            a.placement = Placement::parse(p).context("[arch] placement")?;
        }
        if let Some(b) = self.get("arch", "chip_aligned").and_then(Value::as_bool) {
            a.chip_aligned_chains = b;
        }
        if let Some(v) = self.get_usize("arch", "sync_chips") {
            a.sync_chips = Some(v);
        }
        if a.n_c == 0 || a.n_m == 0 || a.mesh_cols == 0 || a.tiles_per_chip < a.mesh_cols {
            bail!("[arch]: invalid geometry (n_c/n_m/mesh_cols must be > 0, tiles_per_chip >= mesh_cols)");
        }
        Ok(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment: Table IV VGG-11 point
[arch]
n_c = 256
n_m = 256
tiles_per_chip = 240
mesh_cols = 16
pooling = "block-reuse"
sync_chips = 5

[run]
model = "vgg11-cifar10"
images = 4
seed = 42
verbose = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_usize("arch", "n_c"), Some(256));
        assert_eq!(c.get_str("run", "model"), Some("vgg11-cifar10"));
        assert_eq!(c.get("run", "verbose"), Some(&Value::Bool(true)));
        assert_eq!(c.get("nope", "x"), None);
    }

    #[test]
    fn arch_from_config() {
        let c = Config::parse(SAMPLE).unwrap();
        let a = c.arch().unwrap();
        assert_eq!(a.sync_chips, Some(5));
        assert_eq!(a.n_c, 256);
        assert_eq!(a.pooling, PoolingScheme::BlockReuse);
    }

    #[test]
    fn defaults_when_sections_missing() {
        let c = Config::parse("").unwrap();
        let a = c.arch().unwrap();
        assert_eq!(a.n_c, crate::consts::N_C);
        assert_eq!(a.sync_chips, None);
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("= 3").is_err());
        assert!(Config::parse("s = \"open").is_err());
    }

    #[test]
    fn rejects_bad_pooling_and_geometry() {
        let c = Config::parse("[arch]\npooling = \"diagonal\"").unwrap();
        assert!(c.arch().is_err());
        let c = Config::parse("[arch]\nn_c = 0").unwrap();
        assert!(c.arch().is_err());
    }

    #[test]
    fn comments_and_whitespace() {
        let c = Config::parse("  [a]  # section\n k = 1 ; tail\n").unwrap();
        assert_eq!(c.get_usize("a", "k"), Some(1));
    }

    #[test]
    fn weight_duplication_scheme_parses() {
        let c = Config::parse("[arch]\npooling = \"weight-duplication\"").unwrap();
        assert_eq!(c.arch().unwrap().pooling, PoolingScheme::WeightDuplication);
    }

    #[test]
    fn placement_and_alignment_parse() {
        let c = Config::parse(
            "[arch]\nplacement = \"column-major\"\nchip_aligned = true",
        )
        .unwrap();
        let a = c.arch().unwrap();
        assert_eq!(a.placement, Placement::ColumnMajor);
        assert!(a.chip_aligned_chains);
        // defaults when absent
        let a = Config::parse("").unwrap().arch().unwrap();
        assert_eq!(a.placement, Placement::Serpentine);
        assert!(!a.chip_aligned_chains);
        // bad placement rejected
        let c = Config::parse("[arch]\nplacement = \"diagonal\"").unwrap();
        assert!(c.arch().is_err());
    }
}
