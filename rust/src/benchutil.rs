//! Minimal benchmarking helpers shared by the `benches/` harnesses
//! (criterion is unavailable offline; these are deliberately simple:
//! monotonic wallclock, warmup + median-of-N).

use std::time::{Duration, Instant};

/// Run `f` once for warmup, then `iters` times; returns per-iteration
/// durations.
pub fn time_n<F: FnMut()>(iters: usize, mut f: F) -> Vec<Duration> {
    f(); // warmup
    (0..iters)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed()
        })
        .collect()
}

/// Summary statistics of a timing run.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    /// Items-per-second at the median duration; 0 for a degenerate
    /// (zero-length) median instead of dividing by zero.
    pub fn per_second(&self, items: usize) -> f64 {
        crate::sim::stats::safe_rate(items as f64, self.median.as_secs_f64())
    }

    /// Speedup of this run over `baseline` (ratio of medians); 0 when
    /// this run's median is degenerate.
    pub fn speedup_over(&self, baseline: &Stats) -> f64 {
        crate::sim::stats::safe_rate(
            baseline.median.as_secs_f64(),
            self.median.as_secs_f64(),
        )
    }
}

pub fn stats(mut samples: Vec<Duration>) -> Stats {
    samples.sort();
    Stats {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
    }
}

/// Bench `f` and print one aligned row: `name  median (min..max)`.
pub fn bench<F: FnMut()>(name: &str, iters: usize, f: F) -> Stats {
    let s = stats(time_n(iters, f));
    println!(
        "{name:<44} {:>12.3?} (min {:.3?}, max {:.3?}, n={iters})",
        s.median, s.min, s.max
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_orders_samples() {
        let s = stats(vec![
            Duration::from_millis(3),
            Duration::from_millis(1),
            Duration::from_millis(2),
        ]);
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.max, Duration::from_millis(3));
    }

    #[test]
    fn time_n_returns_iters_samples() {
        let v = time_n(5, || { std::hint::black_box(1 + 1); });
        assert_eq!(v.len(), 5);
    }

    #[test]
    fn per_second_and_speedup_guard_zero() {
        let zero = stats(vec![Duration::ZERO]);
        assert_eq!(zero.per_second(100), 0.0);
        let one = stats(vec![Duration::from_secs(1)]);
        assert_eq!(one.per_second(8), 8.0);
        let two = stats(vec![Duration::from_secs(2)]);
        assert!((two.speedup_over(&two) - 1.0).abs() < 1e-12);
        assert!((one.speedup_over(&two) - 2.0).abs() < 1e-12);
        assert_eq!(zero.speedup_over(&one), 0.0);
    }
}
