//! Minimal benchmarking helpers shared by the `benches/` harnesses
//! (criterion is unavailable offline; these are deliberately simple:
//! monotonic wallclock, warmup + median-of-N), plus the tiny JSON
//! writer the benches use to emit machine-readable results
//! (`BENCH_engine.json` / `BENCH_serve.json`) so the perf trajectory
//! is recorded run over run.

use std::time::{Duration, Instant};

/// Run `f` once for warmup, then `iters` times; returns per-iteration
/// durations.
pub fn time_n<F: FnMut()>(iters: usize, mut f: F) -> Vec<Duration> {
    f(); // warmup
    (0..iters)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed()
        })
        .collect()
}

/// Summary statistics of a timing run.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    /// Items-per-second at the median duration; 0 for a degenerate
    /// (zero-length) median instead of dividing by zero.
    pub fn per_second(&self, items: usize) -> f64 {
        crate::sim::stats::safe_rate(items as f64, self.median.as_secs_f64())
    }

    /// Speedup of this run over `baseline` (ratio of medians); 0 when
    /// this run's median is degenerate.
    pub fn speedup_over(&self, baseline: &Stats) -> f64 {
        crate::sim::stats::safe_rate(
            baseline.median.as_secs_f64(),
            self.median.as_secs_f64(),
        )
    }
}

/// Percentile (0..=100) of a timing run; `Duration::ZERO` for an empty
/// set. Delegates to [`crate::serve::metrics::percentile_us`] (the
/// rank formula is unit-agnostic) so both bench JSON reports and the
/// serve metrics rank identically — but feeds it nanoseconds, keeping
/// sub-microsecond per-image times non-zero in `BENCH_engine.json`.
pub fn percentile(samples: &[Duration], p: f64) -> Duration {
    let ns: Vec<u64> = samples.iter().map(|d| d.as_nanos() as u64).collect();
    crate::serve::metrics::percentile_us(&ns, p)
        .map(Duration::from_nanos)
        .unwrap_or(Duration::ZERO)
}

pub fn stats(mut samples: Vec<Duration>) -> Stats {
    samples.sort();
    Stats {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
    }
}

/// Bench `f` and print one aligned row: `name  median (min..max)`.
pub fn bench<F: FnMut()>(name: &str, iters: usize, f: F) -> Stats {
    let s = stats(time_n(iters, f));
    println!(
        "{name:<44} {:>12.3?} (min {:.3?}, max {:.3?}, n={iters})",
        s.median, s.min, s.max
    );
    s
}

/// A hand-rolled JSON object builder (the offline image has no serde;
/// the `serve::wire` codec is request-shaped, so benches use this tiny
/// writer instead). Keys are caller-controlled identifiers; string
/// values are escaped.
#[derive(Default)]
pub struct JsonObj {
    buf: String,
    any: bool,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    fn key(&mut self, k: &str) {
        if self.any {
            self.buf.push(',');
        }
        self.any = true;
        self.buf.push_str(&json_string(k));
        self.buf.push(':');
    }

    pub fn str_field(&mut self, k: &str, v: &str) -> &mut Self {
        self.key(k);
        self.buf.push_str(&json_string(v));
        self
    }

    /// Finite floats are written in Rust's shortest round-trippable
    /// decimal form (full precision — bench medians can be
    /// microseconds expressed in seconds); NaN/inf become `null`
    /// (JSON has no non-finite numbers).
    pub fn f64_field(&mut self, k: &str, v: f64) -> &mut Self {
        self.key(k);
        if v.is_finite() {
            self.buf.push_str(&format!("{v}"));
        } else {
            self.buf.push_str("null");
        }
        self
    }

    pub fn u64_field(&mut self, k: &str, v: u64) -> &mut Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    pub fn bool_field(&mut self, k: &str, v: bool) -> &mut Self {
        self.key(k);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    /// A pre-encoded JSON value (nested object or array).
    pub fn raw_field(&mut self, k: &str, v: &str) -> &mut Self {
        self.key(k);
        self.buf.push_str(v);
        self
    }

    pub fn finish(&self) -> String {
        format!("{{{}}}", self.buf)
    }
}

/// Encode a list of pre-encoded JSON values as an array.
pub fn json_array(items: &[String]) -> String {
    format!("[{}]", items.join(","))
}

/// Encode a string as a JSON string literal (quotes included). There
/// is exactly one string-escaping implementation in this crate: the
/// wire codec's, which is property-tested against its own strict
/// decoder — this delegates to it.
pub fn json_string(s: &str) -> String {
    crate::serve::wire::encode(&crate::serve::wire::Json::Str(s.to_string()))
}

/// Write a JSON document to `path` (plus a trailing newline) and print
/// where it went.
pub fn write_json(path: &str, doc: &str) -> std::io::Result<()> {
    std::fs::write(path, format!("{doc}\n"))?;
    println!("wrote {path}");
    Ok(())
}

/// The value following `flag` in an argv slice (`--flag VALUE` style),
/// shared by the bench harnesses.
pub fn arg_value(argv: &[String], flag: &str) -> Option<String> {
    argv.iter()
        .position(|a| a == flag)
        .and_then(|i| argv.get(i + 1))
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_orders_samples() {
        let s = stats(vec![
            Duration::from_millis(3),
            Duration::from_millis(1),
            Duration::from_millis(2),
        ]);
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.max, Duration::from_millis(3));
    }

    #[test]
    fn time_n_returns_iters_samples() {
        let v = time_n(5, || { std::hint::black_box(1 + 1); });
        assert_eq!(v.len(), 5);
    }

    #[test]
    fn json_obj_builds_valid_documents() {
        let mut inner = JsonObj::new();
        inner.str_field("name", "a\"b\\c\n").u64_field("n", 3);
        let mut o = JsonObj::new();
        o.f64_field("rate", 1.5)
            .f64_field("nan", f64::NAN)
            .bool_field("pass", true)
            .raw_field("items", &json_array(&[inner.finish()]));
        let doc = o.finish();
        assert_eq!(
            doc,
            "{\"rate\":1.5,\"nan\":null,\"pass\":true,\
             \"items\":[{\"name\":\"a\\\"b\\\\c\\n\",\"n\":3}]}"
        );
        // tiny second-valued fields keep full precision
        let mut p = JsonObj::new();
        p.f64_field("s", 2.5e-6);
        assert_eq!(p.finish(), "{\"s\":0.0000025}");
    }

    #[test]
    fn integer_json_round_trips_through_wire_decoder() {
        // The crate's strict wire decoder accepts integer-only JSON —
        // an escaping bug in the builder would fail this parse.
        let mut inner = JsonObj::new();
        inner.str_field("name", "quo\"te\\slash\n").u64_field("n", 3);
        let mut o = JsonObj::new();
        o.u64_field("count", 42)
            .raw_field("items", &json_array(&[inner.finish()]));
        let parsed = crate::serve::wire::decode(&o.finish()).unwrap();
        assert_eq!(
            crate::serve::wire::u64_field(&parsed, "count").unwrap(),
            42
        );
        let items = crate::serve::wire::field(&parsed, "items")
            .unwrap()
            .as_arr()
            .unwrap()
            .to_vec();
        assert_eq!(
            crate::serve::wire::str_field(&items[0], "name").unwrap(),
            "quo\"te\\slash\n"
        );
    }

    #[test]
    fn json_string_escapes_via_wire_codec() {
        assert_eq!(json_string("plain"), "\"plain\"");
        // control chars and quotes survive a strict decode round-trip
        let lit = json_string("a\u{1}\"b\\c\n");
        let parsed = crate::serve::wire::decode(&lit).unwrap();
        assert_eq!(parsed.as_str().unwrap(), "a\u{1}\"b\\c\n");
    }

    #[test]
    fn percentile_nearest_rank() {
        let ms: Vec<Duration> = (1..=10).map(Duration::from_millis).collect();
        assert_eq!(percentile(&ms, 0.0), Duration::from_millis(1));
        assert_eq!(percentile(&ms, 50.0), Duration::from_millis(6));
        assert_eq!(percentile(&ms, 100.0), Duration::from_millis(10));
        assert_eq!(percentile(&[], 50.0), Duration::ZERO);
    }

    #[test]
    fn per_second_and_speedup_guard_zero() {
        let zero = stats(vec![Duration::ZERO]);
        assert_eq!(zero.per_second(100), 0.0);
        let one = stats(vec![Duration::from_secs(1)]);
        assert_eq!(one.per_second(8), 8.0);
        let two = stats(vec![Duration::from_secs(2)]);
        assert!((two.speedup_over(&two) - 1.0).abs() < 1e-12);
        assert!((one.speedup_over(&two) - 2.0).abs() < 1e-12);
        assert_eq!(zero.speedup_over(&one), 0.0);
    }
}
