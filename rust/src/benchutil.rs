//! Minimal benchmarking helpers shared by the `benches/` harnesses
//! (criterion is unavailable offline; these are deliberately simple:
//! monotonic wallclock, warmup + median-of-N).

use std::time::{Duration, Instant};

/// Run `f` once for warmup, then `iters` times; returns per-iteration
/// durations.
pub fn time_n<F: FnMut()>(iters: usize, mut f: F) -> Vec<Duration> {
    f(); // warmup
    (0..iters)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed()
        })
        .collect()
}

/// Summary statistics of a timing run.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

pub fn stats(mut samples: Vec<Duration>) -> Stats {
    samples.sort();
    Stats {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
    }
}

/// Bench `f` and print one aligned row: `name  median (min..max)`.
pub fn bench<F: FnMut()>(name: &str, iters: usize, f: F) -> Stats {
    let s = stats(time_n(iters, f));
    println!(
        "{name:<44} {:>12.3?} (min {:.3?}, max {:.3?}, n={iters})",
        s.median, s.min, s.max
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_orders_samples() {
        let s = stats(vec![
            Duration::from_millis(3),
            Duration::from_millis(1),
            Duration::from_millis(2),
        ]);
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.max, Duration::from_millis(3));
    }

    #[test]
    fn time_n_returns_iters_samples() {
        let v = time_n(5, || { std::hint::black_box(1 + 1); });
        assert_eq!(v.len(), 5);
    }
}
