//! The Domino instruction set (paper Table I / Table II).
//!
//! Every ROFM holds a 128-entry, 16-bit-wide schedule table (Table III).
//! A counter indexes the table modulo the layer's period; the fetched
//! word controls that cycle's receive, add, buffer, compute and transmit
//! actions. Two instruction types exist:
//!
//! * **C-type** — convolution/FC steady-state: receive partial sums,
//!   accumulate with the local PE output, push/pop group-sums in the
//!   ROFM buffer, transmit.
//! * **M-type** — "last row" duties: apply the computation-unit function
//!   (Table II: Add / Act / Cmp / Mul / Bp) to finished sums — activation,
//!   max/average pooling, or bypass for skip connections.
//!
//! The paper's Table I gives field names (`Rx Ctrl`, `Sum`, `Buffer`,
//! `Tx Ctrl`, `Opc.`, `Func.`) but its typesetting leaves exact bit
//! positions ambiguous; this module fixes a concrete encoding (documented
//! per field below) and the whole stack — compiler, simulator, traces —
//! uses it. Encode/decode round-trip is property-tested.
//!
//! ```text
//! C-type (bit 0 = 0):
//!   [15:11] rx_ctrl   5 bits, one per source {N, E, S, W, PE}
//!   [10]    sum       accumulate received values + PE into running sum
//!   [9:8]   buffer    00 none | 01 push | 10 pop | 11 pop+push
//!   [7:5]   tx_ctrl   000 none | 1dd transmit to direction dd
//!   [4:1]   opc       C-opcode (Nop/Acc/AccOut/Out)
//! M-type (bit 0 = 1):
//!   [15:11] rx_ctrl   as above
//!   [10:7]  func      Table II function selector
//!   [7:5]   -- (func overlaps unused tx bits; tx_ctrl is [6:5])
//!   [6:5]   tx_ctrl   00 none | 01 out-port | 10 next-layer | 11 local
//!   [4:1]   opc       M-opcode (Apply/Flush)
//! ```

/// Receive sources, one bit each in `rx_ctrl`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RxSource {
    North = 4,
    East = 3,
    South = 2,
    West = 1,
    /// The local PE's partial-sum output port.
    Pe = 0,
}

impl RxSource {
    pub const ALL: [RxSource; 5] = [
        RxSource::North,
        RxSource::East,
        RxSource::South,
        RxSource::West,
        RxSource::Pe,
    ];

    pub fn mask(self) -> u8 {
        1 << (self as u8)
    }
}

/// Bit-set of receive sources (5 bits).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct RxCtrl(pub u8);

impl RxCtrl {
    pub const NONE: RxCtrl = RxCtrl(0);

    pub fn with(mut self, src: RxSource) -> Self {
        self.0 |= src.mask();
        self
    }

    pub fn contains(self, src: RxSource) -> bool {
        self.0 & src.mask() != 0
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }
}

/// ROFM buffer operation for group-sums.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BufferOp {
    #[default]
    None = 0,
    /// Enqueue the running sum as a new group-sum.
    Push = 1,
    /// Dequeue the oldest group-sum into the adder path.
    Pop = 2,
    /// Dequeue and enqueue in the same cycle (steady-state pipelining).
    PopPush = 3,
}

impl BufferOp {
    fn from_bits(b: u16) -> Self {
        match b & 0b11 {
            0 => BufferOp::None,
            1 => BufferOp::Push,
            2 => BufferOp::Pop,
            _ => BufferOp::PopPush,
        }
    }
}

/// Transmit control.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TxCtrl {
    #[default]
    None = 0,
    /// Transmit on the tile's configured output direction (to the next
    /// tile of this layer's chain).
    Chain = 1,
    /// Transmit to the next layer's tile array (layer hand-off).
    NextLayer = 2,
    /// Deliver locally (final network output / chip boundary).
    Local = 3,
}

impl TxCtrl {
    fn from_bits(b: u16) -> Self {
        match b & 0b11 {
            0 => TxCtrl::None,
            1 => TxCtrl::Chain,
            2 => TxCtrl::NextLayer,
            _ => TxCtrl::Local,
        }
    }
}

/// C-type opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum COpcode {
    /// Do nothing this cycle (shielded slot — e.g. stride skipping).
    #[default]
    Nop = 0,
    /// Accumulate (rx + PE into running sum), keep result local.
    Acc = 1,
    /// Accumulate and transmit the result.
    AccOut = 2,
    /// Transmit the running/popped sum without accumulating.
    Out = 3,
}

impl COpcode {
    fn from_bits(b: u16) -> Self {
        match b & 0b1111 {
            1 => COpcode::Acc,
            2 => COpcode::AccOut,
            3 => COpcode::Out,
            _ => COpcode::Nop,
        }
    }
}

/// Table II computation-unit functions (M-type `func` field).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Func {
    /// Adder — partial-sum accumulation.
    #[default]
    Add = 0,
    /// Activation (ReLU in the evaluated networks).
    Act = 1,
    /// Comparison — max pooling.
    Cmp = 2,
    /// Multiplication with a scaling factor — average pooling.
    Mul = 3,
    /// Direct transmission — "skip" connection.
    Bp = 4,
    /// Requantize an i32 group-sum to i8 (shift+saturate). The paper
    /// folds this into Act; we make it explicit so linear layers
    /// (conv without ReLU) are expressible.
    Quant = 5,
}

impl Func {
    fn from_bits(b: u16) -> Self {
        match b & 0b1111 {
            1 => Func::Act,
            2 => Func::Cmp,
            3 => Func::Mul,
            4 => Func::Bp,
            5 => Func::Quant,
            _ => Func::Add,
        }
    }
}

/// M-type opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MOpcode {
    /// Apply `func` to the incoming value(s) this cycle.
    #[default]
    Apply = 0,
    /// Apply and emit the completed result (end of a pooling window).
    ApplyOut = 1,
}

impl MOpcode {
    fn from_bits(b: u16) -> Self {
        match b & 0b1111 {
            1 => MOpcode::ApplyOut,
            _ => MOpcode::Apply,
        }
    }
}

/// A decoded instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Instr {
    C {
        rx: RxCtrl,
        sum: bool,
        buffer: BufferOp,
        tx: TxCtrl,
        opc: COpcode,
    },
    M {
        rx: RxCtrl,
        func: Func,
        tx: TxCtrl,
        opc: MOpcode,
    },
}

impl Default for Instr {
    fn default() -> Self {
        Instr::nop()
    }
}

impl Instr {
    /// The canonical idle instruction.
    pub fn nop() -> Self {
        Instr::C {
            rx: RxCtrl::NONE,
            sum: false,
            buffer: BufferOp::None,
            tx: TxCtrl::None,
            opc: COpcode::Nop,
        }
    }

    pub fn is_nop(&self) -> bool {
        matches!(
            self,
            Instr::C {
                rx: RxCtrl(0),
                sum: false,
                buffer: BufferOp::None,
                tx: TxCtrl::None,
                opc: COpcode::Nop,
            }
        )
    }

    /// Encode to the 16-bit schedule-table word.
    pub fn encode(&self) -> u16 {
        match *self {
            Instr::C {
                rx,
                sum,
                buffer,
                tx,
                opc,
            } => {
                let mut w: u16 = 0; // bit 0 = 0 (C-type)
                w |= (opc as u16) << 1;
                w |= (tx as u16) << 5; // [6:5]; bit 7 unused for C tx
                w |= (buffer as u16) << 8;
                w |= (sum as u16) << 10;
                w |= (rx.0 as u16) << 11;
                w
            }
            Instr::M { rx, func, tx, opc } => {
                let mut w: u16 = 1; // bit 0 = 1 (M-type)
                w |= (opc as u16) << 1;
                w |= (tx as u16) << 5;
                w |= (func as u16) << 7;
                w |= (rx.0 as u16) << 11;
                w
            }
        }
    }

    /// Decode a 16-bit schedule-table word.
    pub fn decode(w: u16) -> Self {
        let rx = RxCtrl(((w >> 11) & 0b11111) as u8);
        if w & 1 == 0 {
            Instr::C {
                rx,
                sum: (w >> 10) & 1 == 1,
                buffer: BufferOp::from_bits(w >> 8),
                tx: TxCtrl::from_bits(w >> 5),
                opc: COpcode::from_bits(w >> 1),
            }
        } else {
            Instr::M {
                rx,
                func: Func::from_bits(w >> 7),
                tx: TxCtrl::from_bits(w >> 5),
                opc: MOpcode::from_bits(w >> 1),
            }
        }
    }

    /// Shield this instruction (paper Section II-C: for stride != 1 "the
    /// compiler will shield certain bits in control words to skip some
    /// actions"): suppress sum/buffer/tx actions but keep receives so
    /// dataflow timing is preserved.
    pub fn shielded(&self) -> Self {
        match *self {
            Instr::C { rx, .. } => Instr::C {
                rx,
                sum: false,
                buffer: BufferOp::None,
                tx: TxCtrl::None,
                opc: COpcode::Nop,
            },
            Instr::M { rx, .. } => Instr::M {
                rx,
                func: Func::Bp,
                tx: TxCtrl::None,
                opc: MOpcode::Apply,
            },
        }
    }
}

impl std::fmt::Display for Instr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        fn rx_str(rx: RxCtrl) -> String {
            if rx.is_empty() {
                return "-".into();
            }
            let mut s = String::new();
            for (src, ch) in [
                (RxSource::North, 'N'),
                (RxSource::East, 'E'),
                (RxSource::South, 'S'),
                (RxSource::West, 'W'),
                (RxSource::Pe, 'P'),
            ] {
                if rx.contains(src) {
                    s.push(ch);
                }
            }
            s
        }
        match *self {
            Instr::C {
                rx,
                sum,
                buffer,
                tx,
                opc,
            } => write!(
                f,
                "C[rx={} sum={} buf={:?} tx={:?} opc={:?}]",
                rx_str(rx),
                sum as u8,
                buffer,
                tx,
                opc
            ),
            Instr::M { rx, func, tx, opc } => write!(
                f,
                "M[rx={} func={:?} tx={:?} opc={:?}]",
                rx_str(rx),
                func,
                tx,
                opc
            ),
        }
    }
}

/// A periodic instruction schedule: the contents of one ROFM's schedule
/// table plus its period. The ROFM executes `table[counter % period]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schedule {
    pub table: Vec<Instr>,
    /// Counter offset applied before the modulo (aligns a tile's phase
    /// with the arrival time of its first input packet).
    pub phase: usize,
}

impl Schedule {
    /// An always-idle schedule.
    pub fn idle() -> Self {
        Self {
            table: vec![Instr::nop()],
            phase: 0,
        }
    }

    pub fn period(&self) -> usize {
        self.table.len()
    }

    /// Instruction for absolute cycle `t`.
    pub fn at(&self, t: usize) -> Instr {
        self.table[(t + self.phase) % self.table.len()]
    }

    /// Check the schedule fits the hardware table (128 x 16 b, Table III).
    pub fn fits_hardware(&self) -> bool {
        self.table.len() <= crate::consts::SCHEDULE_TABLE_ENTRIES
    }

    /// Number of run-length-encoded entries: the hardware stores the
    /// periodic program as (instruction, repeat) runs — the steady-state
    /// slot dominates a conv row, so a period of `2(P+W)` cycles
    /// compresses to a handful of table entries. This is what must fit
    /// the 128-entry table.
    pub fn compressed_len(&self) -> usize {
        let mut runs = 0usize;
        let mut prev: Option<&Instr> = None;
        for i in &self.table {
            if prev != Some(i) {
                runs += 1;
                prev = Some(i);
            }
        }
        runs.max(1)
    }

    /// Encoded table image (what would be written into the 16 b x 128
    /// SRAM at configuration time).
    pub fn encode(&self) -> Vec<u16> {
        self.table.iter().map(Instr::encode).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{for_all, Rng};

    fn arb_instr(rng: &mut Rng) -> Instr {
        let rx = RxCtrl((rng.below(32)) as u8);
        if rng.chance(0.5) {
            Instr::C {
                rx,
                sum: rng.chance(0.5),
                buffer: BufferOp::from_bits(rng.below(4) as u16),
                tx: TxCtrl::from_bits(rng.below(4) as u16),
                opc: COpcode::from_bits(rng.below(4) as u16),
            }
        } else {
            Instr::M {
                rx,
                func: Func::from_bits(rng.below(6) as u16),
                tx: TxCtrl::from_bits(rng.below(4) as u16),
                opc: MOpcode::from_bits(rng.below(2) as u16),
            }
        }
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        for_all("isa_roundtrip", 200, |rng| {
            let i = arb_instr(rng);
            let w = i.encode();
            assert_eq!(Instr::decode(w), i, "word {w:#06x}");
        });
    }

    #[test]
    fn type_bit_is_bit_zero() {
        let c = Instr::nop().encode();
        assert_eq!(c & 1, 0);
        let m = Instr::M {
            rx: RxCtrl::NONE,
            func: Func::Act,
            tx: TxCtrl::None,
            opc: MOpcode::Apply,
        }
        .encode();
        assert_eq!(m & 1, 1);
    }

    #[test]
    fn nop_encodes_to_zero() {
        assert_eq!(Instr::nop().encode(), 0);
        assert!(Instr::decode(0).is_nop());
    }

    #[test]
    fn shielding_keeps_rx_suppresses_actions() {
        let i = Instr::C {
            rx: RxCtrl::NONE.with(RxSource::West).with(RxSource::Pe),
            sum: true,
            buffer: BufferOp::PopPush,
            tx: TxCtrl::Chain,
            opc: COpcode::AccOut,
        };
        let s = i.shielded();
        match s {
            Instr::C {
                rx,
                sum,
                buffer,
                tx,
                opc,
            } => {
                assert!(rx.contains(RxSource::West) && rx.contains(RxSource::Pe));
                assert!(!sum);
                assert_eq!(buffer, BufferOp::None);
                assert_eq!(tx, TxCtrl::None);
                assert_eq!(opc, COpcode::Nop);
            }
            _ => panic!("shielded C stays C"),
        }
    }

    #[test]
    fn schedule_indexing_with_phase() {
        let s = Schedule {
            table: vec![
                Instr::nop(),
                Instr::C {
                    rx: RxCtrl::NONE.with(RxSource::Pe),
                    sum: true,
                    buffer: BufferOp::None,
                    tx: TxCtrl::None,
                    opc: COpcode::Acc,
                },
            ],
            phase: 1,
        };
        assert!(!s.at(0).is_nop());
        assert!(s.at(1).is_nop());
        assert_eq!(s.period(), 2);
    }

    #[test]
    fn hardware_fit_bound() {
        let ok = Schedule {
            table: vec![Instr::nop(); 128],
            phase: 0,
        };
        assert!(ok.fits_hardware());
        let too_big = Schedule {
            table: vec![Instr::nop(); 129],
            phase: 0,
        };
        assert!(!too_big.fits_hardware());
    }

    #[test]
    fn rx_ctrl_masks_are_distinct() {
        let mut seen = 0u8;
        for s in RxSource::ALL {
            assert_eq!(seen & s.mask(), 0);
            seen |= s.mask();
        }
        assert_eq!(seen, 0b11111);
    }

    #[test]
    fn encoded_schedule_matches_words() {
        let s = Schedule {
            table: vec![Instr::nop(); 3],
            phase: 0,
        };
        assert_eq!(s.encode(), vec![0, 0, 0]);
    }
}
