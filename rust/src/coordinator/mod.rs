//! The Domino coordinator — the paper's system contribution, organised
//! as an explicit mapping plane.
//!
//! * [`isa`] — the 16-bit C-type/M-type instruction encoding (Table I)
//!   and the periodic [`isa::Schedule`] abstraction.
//! * [`plan`] — the mapping-plane IR: **allocate** (logical tile
//!   arrays & duplication per layer) → **place** (pluggable
//!   [`Placement`] strategy: serpentine baseline or column-major, plus
//!   chip-aligned variants) → **partition** (240-tile chips), yielding
//!   a weight-free [`MappingPlan`]. The place phase is fault-aware: a
//!   [`TileMask`] of known-bad tiles/links (from the fault plane's
//!   detection path) slides whole chains forward until they clear,
//!   so a model re-maps around a bad resource with bit-exact weights
//!   ([`Compiler::compile_with_weights_masked`]) at a measurable
//!   span/latency/energy cost.
//! * [`mapper`] — the compiler around the plan: [`Compiler::plan`]
//!   builds the IR, [`Compiler::materialize`] schedules it (per-tile
//!   periodic instruction programs, RIFM configs, stationary weight
//!   blocks), and [`Compiler::compile`] is the thin composition of the
//!   two.
//! * [`explore`] — the cost-model-driven mapping explorer: enumerate
//!   candidate `MappingChoice`s (pooling × placement × mesh shape ×
//!   chip alignment), score each analytically (perfmodel timing,
//!   Table III energy, worst-link NoC load — no cycle simulation) and
//!   rank per objective. Winners feed the serving layer's per-model
//!   mappings (`domino map explore`, `serve::api::MappingSpec`).
//! * [`schedule`] — generates each tile's periodic instruction program
//!   (period `2(P+W)` for stride-1 conv rows, `2·S_p` for pooling,
//!   Section II-C) including stride shielding.
//! * [`program`] — the compiled artifact: per-tile configuration
//!   (weights, RIFM config, ROFM schedule, placement) grouped into
//!   pipeline stages, consumed by `sim::engine`.

pub mod explore;
pub mod isa;
pub mod mapper;
pub mod plan;
pub mod program;
pub mod schedule;

pub use mapper::{ArchConfig, Compiler, PoolingScheme};
pub use plan::{MappingPlan, Placement, TileMask};
pub use program::{Program, Stage, StageKind};
