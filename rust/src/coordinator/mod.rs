//! The Domino coordinator — the paper's system contribution.
//!
//! * [`isa`] — the 16-bit C-type/M-type instruction encoding (Table I)
//!   and the periodic [`isa::Schedule`] abstraction.
//! * [`mapper`] — allocates each weight layer onto a tile array
//!   (`K² x ⌈C/N_c⌉ x ⌈M/N_m⌉` tiles for conv, `⌈C_in/N_c⌉ x
//!   ⌈C_out/N_m⌉` for FC), places chains serpentine in the mesh and
//!   partitions across chips (240 tiles/chip).
//! * [`schedule`] — generates each tile's periodic instruction program
//!   (period `2(P+W)` for stride-1 conv rows, `2·S_p` for pooling,
//!   Section II-C) including stride shielding.
//! * [`program`] — the compiled artifact: per-tile configuration
//!   (weights, RIFM config, ROFM schedule, placement) grouped into
//!   pipeline stages, consumed by `sim::engine`.

pub mod isa;
pub mod mapper;
pub mod program;
pub mod schedule;

pub use mapper::{ArchConfig, Compiler, PoolingScheme};
pub use program::{Program, Stage, StageKind};
