//! The Domino mapping compiler (paper Sections II-C, III).
//!
//! Turns a [`Network`] + weights into a [`Program`] through the
//! explicit phases of the mapping plane (`super::plan`):
//!
//! 1. **allocate** — every weight layer becomes a logical tile array:
//!    CONV gets `K² · ⌈C/N_c⌉ · ⌈M/N_m⌉` tiles (Section III-B), FC a
//!    `⌈C_in/N_c⌉ × ⌈C_out/N_m⌉` grid (Section III-A, Fig. 2); pooling
//!    directly after a conv is fused into the conv's hand-off (Section
//!    III-C) — under block reuse it costs no tiles, under weight
//!    duplication the conv array is replicated `K_p²` times; residual
//!    skips route through RIFM→ROFM shortcuts, projected skips get a
//!    1x1 conv array;
//! 2. **place** — chains are pinned to mesh coordinates through the
//!    arch's pluggable [`Placement`] strategy (serpentine baseline or
//!    column-major; every partial-sum hop stays mesh-local either way);
//! 3. **schedule** — [`Compiler::materialize`] generates every placed
//!    tile's periodic ROFM schedule (`super::schedule`), RIFM
//!    configuration and stationary weight block;
//! 4. **partition** — the placed span is cut into chips (240 tiles
//!    each in the paper's evaluation).
//!
//! [`Compiler::compile`] is the thin composition of
//! [`Compiler::plan`] (phases 1, 2, 4 — the [`MappingPlan`] IR) and
//! [`Compiler::materialize`] (phase 3), bit-identical to the former
//! single-pass compiler.

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::plan::{ConvPlan, FcPlan, LayerPlan, MappingPlan, Placement, TileMask};
use crate::coordinator::program::*;
use crate::coordinator::schedule::{
    conv_tile_schedule, fc_tile_schedule, ConvGeometry, ConvRole,
};
use crate::model::refcompute::{LayerWeights, Weights};
use crate::model::{LayerKind, Network, Projection, TensorShape};
use crate::tile::rifm::RifmConfig;

/// How pooling after a conv layer is realised (paper Fig. 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolingScheme {
    /// Fig. 4(c): activation results are stored in the last tile and
    /// compared as new results arrive. No extra tiles; upstream arrays
    /// run at full rate.
    BlockReuse,
    /// Fig. 4(b): weights are duplicated `K_p²` times so a full pooling
    /// window is produced every cycle, keeping layers synchronised.
    WeightDuplication,
}

impl PoolingScheme {
    /// Canonical config/wire name.
    pub fn name(self) -> &'static str {
        match self {
            PoolingScheme::BlockReuse => "block-reuse",
            PoolingScheme::WeightDuplication => "weight-duplication",
        }
    }

    /// Parse a config/wire name (case-insensitive, `_`/`-`
    /// interchangeable).
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().replace('_', "-").as_str() {
            "block-reuse" => Ok(PoolingScheme::BlockReuse),
            "weight-duplication" => Ok(PoolingScheme::WeightDuplication),
            other => bail!(
                "unknown pooling scheme {other:?} (use \"block-reuse\" or \
                 \"weight-duplication\")"
            ),
        }
    }

    /// Both schemes, for sweeps.
    pub const ALL: [PoolingScheme; 2] =
        [PoolingScheme::BlockReuse, PoolingScheme::WeightDuplication];
}

/// Architecture parameters (paper Section IV-A defaults).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArchConfig {
    /// Crossbar rows per PE.
    pub n_c: usize,
    /// Crossbar columns per PE.
    pub n_m: usize,
    /// Tiles per chip (Table IV: 240).
    pub tiles_per_chip: usize,
    /// Mesh width (columns) per chip; 240 tiles = 16 x 15.
    pub mesh_cols: usize,
    pub pooling: PoolingScheme,
    /// How chains are pinned to mesh coordinates (the place phase's
    /// strategy; see `coordinator::plan`).
    pub placement: Placement,
    /// Keep every psum chain within one chip: when a chain would
    /// straddle a 240-tile chip boundary, pad the allocation cursor to
    /// the next chip so all its partial-sum hops stay on the cheap
    /// mesh links instead of the 0.55 pJ/b inter-chip transceivers.
    /// Costs a few pad tiles; saves inter-chip energy (ablation
    /// `benches/ablation_chip_align.rs`).
    pub chip_aligned_chains: bool,
    /// Layer-synchronization duplication budget, in chips (paper
    /// Table IV: "# of CIM cores/chip & chips" — e.g. 240x5 for
    /// VGG-11). When set, the compiler water-fills weight duplication
    /// over the bottleneck conv stages until the budget is exhausted,
    /// equalizing stage periods ("maintain synchronization among
    /// layers", Section III-C). `None` disables throughput duplication
    /// (tile count is the Section III-B minimum).
    pub sync_chips: Option<usize>,
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self {
            n_c: crate::consts::N_C,
            n_m: crate::consts::N_M,
            tiles_per_chip: crate::consts::TILES_PER_CHIP,
            mesh_cols: 16,
            pooling: PoolingScheme::BlockReuse,
            placement: Placement::Serpentine,
            chip_aligned_chains: false,
            sync_chips: None,
        }
    }
}

impl ArchConfig {
    /// A small-crossbar config used in tests so multi-block paths are
    /// exercised without 256-wide layers.
    pub fn tiny(n: usize) -> Self {
        Self {
            n_c: n,
            n_m: n,
            ..Self::default()
        }
    }

    /// The paper's Table IV operating point for a given chip count
    /// (240 tiles/chip, duplication water-filled to the budget).
    pub fn table4(chips: usize) -> Self {
        Self {
            sync_chips: Some(chips),
            ..Self::default()
        }
    }
}

/// The compiler.
#[derive(Clone, Debug)]
pub struct Compiler {
    pub arch: ArchConfig,
    /// Seed for synthetic weights when none are supplied.
    pub weight_seed: u64,
    /// Skeleton mode: skip materializing per-tile weight blocks.
    /// Mapping, schedules, the analytic perfmodel, energy pricing and
    /// flow analysis are all weight-independent, and VGG-scale weight
    /// materialization costs ~0.6 s per compile (§Perf); skeleton
    /// programs must not be fed to the functional simulator.
    skeleton: bool,
}

impl Default for Compiler {
    fn default() -> Self {
        Self {
            arch: ArchConfig::default(),
            weight_seed: 0xD0_31_10,
            skeleton: false,
        }
    }
}

impl Compiler {
    pub fn new(arch: ArchConfig) -> Self {
        Self {
            arch,
            ..Self::default()
        }
    }

    /// Compile with freshly generated (seeded) weights.
    pub fn compile(&self, net: &Network) -> Result<Program> {
        if self.skeleton {
            let weights = Weights::empty(net);
            return self.compile_with_weights(net, &weights);
        }
        let weights = Weights::random(net, self.weight_seed)?;
        self.compile_with_weights(net, &weights)
    }

    /// Compile for *analysis only* (mapping / timing / energy / NoC
    /// flows): tile weight blocks are left empty, which skips both
    /// synthetic-weight generation and the per-tile weight gather —
    /// ~25x faster on VGG-scale networks. The returned program must
    /// not be run through the functional `Simulator`.
    pub fn compile_analysis(&self, net: &Network) -> Result<Program> {
        let mut c = self.clone();
        c.skeleton = true;
        c.compile(net)
    }

    /// Build the mapping plan (allocate → place → partition; see
    /// `super::plan`): the explicit IR between "what tile arrays does
    /// this network need" and the scheduled, weight-bearing
    /// [`Program`]. Weight-free and cheap — the mapping explorer
    /// builds many of these per model.
    pub fn plan(&self, net: &Network) -> Result<MappingPlan> {
        crate::coordinator::plan::build(net, &self.arch)
    }

    /// [`Self::plan`], routing placement around a [`TileMask`] of
    /// known-bad tiles/links (the fault-recovery path — see
    /// `coordinator::plan`'s fault-aware placement docs). An empty
    /// mask reproduces [`Self::plan`] bit-for-bit.
    pub fn plan_masked(&self, net: &Network, mask: &TileMask) -> Result<MappingPlan> {
        crate::coordinator::plan::build_masked(net, &self.arch, mask)
    }

    /// Compile with caller-provided weights (e.g. trained weights loaded
    /// from the JAX golden model): the thin composition of
    /// [`Self::plan`] and [`Self::materialize`].
    pub fn compile_with_weights(&self, net: &Network, weights: &Weights) -> Result<Program> {
        let plan = self.plan(net)?;
        self.materialize(net, weights, &plan)
    }

    /// [`Self::compile_with_weights`] around a [`TileMask`]: the same
    /// weights, scheduled onto a placement that provably avoids every
    /// masked tile/link. This is how a model re-maps around a detected
    /// fault bit-exactly — outputs are weight- and schedule-determined,
    /// so the re-placed program stays refcompute-exact while the bad
    /// resources go unused (the measurable cost is extra span: more
    /// pad tiles, possibly more chips).
    pub fn compile_with_weights_masked(
        &self,
        net: &Network,
        weights: &Weights,
        mask: &TileMask,
    ) -> Result<Program> {
        let plan = self.plan_masked(net, mask)?;
        self.materialize(net, weights, &plan)
    }

    /// [`Self::compile`] (seeded weights) around a [`TileMask`].
    pub fn compile_masked(&self, net: &Network, mask: &TileMask) -> Result<Program> {
        if self.skeleton {
            let weights = Weights::empty(net);
            return self.compile_with_weights_masked(net, &weights, mask);
        }
        let weights = Weights::random(net, self.weight_seed)?;
        self.compile_with_weights_masked(net, &weights, mask)
    }

    /// [`Self::compile_analysis`] around a [`TileMask`] (skeleton
    /// program: mapping/timing/energy only, not runnable).
    pub fn compile_analysis_masked(&self, net: &Network, mask: &TileMask) -> Result<Program> {
        let mut c = self.clone();
        c.skeleton = true;
        c.compile_masked(net, mask)
    }

    /// The schedule phase: turn a [`MappingPlan`] into the runnable
    /// [`Program`] — per-tile periodic ROFM schedules, RIFM
    /// configuration and stationary weight blocks, at the plan's
    /// placement. The plan must have been built for this compiler's
    /// [`ArchConfig`].
    pub fn materialize(
        &self,
        net: &Network,
        weights: &Weights,
        plan: &MappingPlan,
    ) -> Result<Program> {
        ensure!(
            plan.arch == self.arch,
            "mapping plan was built for a different ArchConfig"
        );
        let shapes = net.shapes()?;
        if weights.per_layer.len() != net.layers.len() {
            bail!("weights cover {} layers, network has {}", weights.per_layer.len(), net.layers.len());
        }
        ensure!(
            plan.layers.len() == net.layers.len(),
            "mapping plan covers {} layers, network has {}",
            plan.layers.len(),
            net.layers.len()
        );
        let mut stages: Vec<Stage> = Vec::new();
        let mut in_shape = net.input;
        // map network layer index -> stage index (for ResAdd sources)
        let mut layer_to_stage: Vec<Option<usize>> = vec![None; net.layers.len()];
        // duplication factor of the stage feeding the current layer:
        // element-wise stages (pool, res-add) inherit the incoming
        // stream rate set by their upstream conv array
        let mut prev_dup = 1usize;

        let mut i = 0usize;
        while i < net.layers.len() {
            let layer = &net.layers[i];
            let out_shape = shapes[i];
            match &layer.kind {
                LayerKind::Conv2d {
                    out_ch,
                    kernel,
                    stride,
                    padding,
                    relu,
                } => {
                    // fuse a directly following pooling layer
                    let fused_pool = match net.layers.get(i + 1).map(|l| &l.kind) {
                        Some(LayerKind::MaxPool2d { kernel, stride }) => Some(PoolSpec {
                            max: true,
                            kernel: *kernel,
                            stride: *stride,
                        }),
                        Some(LayerKind::AvgPool2d { kernel, stride }) => Some(PoolSpec {
                            max: false,
                            kernel: *kernel,
                            stride: *stride,
                        }),
                        _ => None,
                    };
                    let lw = match &weights.per_layer[i] {
                        LayerWeights::Conv { w } => w.as_slice(),
                        LayerWeights::None if self.skeleton => &[],
                        _ => bail!("layer {i}: conv weights missing"),
                    };
                    let LayerPlan::Conv(cp) = &plan.layers[i] else {
                        bail!("layer {i}: mapping plan expected a conv allocation");
                    };
                    let stage = self.build_conv_stage(
                        in_shape,
                        out_shape,
                        *out_ch,
                        *kernel,
                        *stride,
                        *padding,
                        *relu,
                        layer.requant_shift,
                        lw,
                        fused_pool,
                        cp,
                    )?;
                    layer_to_stage[i] = Some(stages.len());
                    prev_dup = cp.dup;
                    let fused = fused_pool.is_some();
                    stages.push(Stage {
                        layer: i,
                        name: layer.name.clone(),
                        kind: StageKind::Conv(stage),
                    });
                    if fused {
                        // the pool layer maps to the same stage
                        layer_to_stage[i + 1] = Some(stages.len() - 1);
                        in_shape = shapes[i + 1];
                        i += 2;
                        continue;
                    }
                }
                LayerKind::Fc { out_features, relu } => {
                    let lw = match &weights.per_layer[i] {
                        LayerWeights::Fc { w } => w.as_slice(),
                        LayerWeights::None if self.skeleton => &[],
                        _ => bail!("layer {i}: fc weights missing"),
                    };
                    let LayerPlan::Fc(fp) = &plan.layers[i] else {
                        bail!("layer {i}: mapping plan expected an fc allocation");
                    };
                    let stage = self.build_fc_stage(
                        in_shape.c,
                        *out_features,
                        *relu,
                        layer.requant_shift,
                        lw,
                        fp,
                    )?;
                    layer_to_stage[i] = Some(stages.len());
                    prev_dup = 1;
                    stages.push(Stage {
                        layer: i,
                        name: layer.name.clone(),
                        kind: StageKind::Fc(stage),
                    });
                }
                LayerKind::MaxPool2d { kernel, stride } => {
                    layer_to_stage[i] = Some(stages.len());
                    stages.push(Stage {
                        layer: i,
                        name: layer.name.clone(),
                        kind: StageKind::Pool(PoolStage {
                            max: true,
                            kernel: *kernel,
                            stride: *stride,
                            in_shape,
                            out_shape,
                            dup: prev_dup,
                        }),
                    });
                }
                LayerKind::AvgPool2d { kernel, stride } => {
                    layer_to_stage[i] = Some(stages.len());
                    stages.push(Stage {
                        layer: i,
                        name: layer.name.clone(),
                        kind: StageKind::Pool(PoolStage {
                            max: false,
                            kernel: *kernel,
                            stride: *stride,
                            in_shape,
                            out_shape,
                            dup: prev_dup,
                        }),
                    });
                }
                LayerKind::ResAdd { from, proj } => {
                    let from_stage = layer_to_stage[*from]
                        .with_context(|| format!("layer {i}: skip source {from} unmapped"))?;
                    let proj_stage = match proj {
                        Some(p) => {
                            let lw = match &weights.per_layer[i] {
                                LayerWeights::Proj { w } => w.as_slice(),
                                LayerWeights::None if self.skeleton => &[],
                                _ => bail!("layer {i}: projection weights missing"),
                            };
                            let LayerPlan::Conv(cp) = &plan.layers[i] else {
                                bail!(
                                    "layer {i}: mapping plan expected a projection allocation"
                                );
                            };
                            Some(self.build_projection_stage(
                                shapes[*from],
                                p,
                                layer.requant_shift,
                                lw,
                                cp,
                            )?)
                        }
                        None => None,
                    };
                    layer_to_stage[i] = Some(stages.len());
                    // the add unit runs at the slowest incoming rate:
                    // main path, skip-source stage, projection array
                    let src_dup = match &stages[from_stage].kind {
                        StageKind::Conv(c) => c.dup,
                        StageKind::Pool(p) => p.dup,
                        StageKind::Res(r) => r.dup,
                        _ => 1,
                    };
                    let res_dup = prev_dup
                        .min(src_dup)
                        .min(proj_stage.as_ref().map(|p| p.dup).unwrap_or(usize::MAX));
                    prev_dup = res_dup;
                    stages.push(Stage {
                        layer: i,
                        name: layer.name.clone(),
                        kind: StageKind::Res(ResStage {
                            from_stage,
                            proj: proj_stage,
                            shape: out_shape,
                            dup: res_dup,
                        }),
                    });
                }
                LayerKind::Flatten => {
                    layer_to_stage[i] = Some(stages.len());
                    stages.push(Stage {
                        layer: i,
                        name: layer.name.clone(),
                        kind: StageKind::Flatten,
                    });
                }
            }
            in_shape = out_shape;
            i += 1;
        }

        Ok(Program {
            net: net.clone(),
            arch: self.arch,
            stages,
            total_tiles: plan.total_tiles,
            chips: plan.chips,
        })
    }

    /// Split `n` into blocks of at most `cap`: returns (lo, hi) pairs.
    fn blocks(n: usize, cap: usize) -> Vec<(usize, usize)> {
        (0..n.div_ceil(cap))
            .map(|b| (b * cap, ((b + 1) * cap).min(n)))
            .collect()
    }

    #[allow(clippy::too_many_arguments)]
    fn build_conv_stage(
        &self,
        in_shape: TensorShape,
        out_shape: TensorShape,
        out_ch: usize,
        k: usize,
        stride: usize,
        padding: usize,
        relu: bool,
        shift: u32,
        w: &[i8], // [M][C][K][K]
        fused_pool: Option<PoolSpec>,
        plan: &ConvPlan,
    ) -> Result<ConvStage> {
        let c_in = in_shape.c;
        let dup = plan.dup;
        let g = ConvGeometry::new(k, stride, padding, in_shape.h, in_shape.w);
        let cblks = Self::blocks(c_in, self.arch.n_c);
        let mblks = Self::blocks(out_ch, self.arch.n_m);
        ensure!(
            plan.chains.len() == mblks.len() && plan.chain_len == k * k * cblks.len(),
            "conv stage needs {} chains of {} tiles, plan has {} of {}",
            mblks.len(),
            k * k * cblks.len(),
            plan.chains.len(),
            plan.chain_len
        );
        let mut chains = Vec::new();
        for (mb, &(m_lo, m_hi)) in mblks.iter().enumerate() {
            let cols = m_hi - m_lo;
            let mut tiles = Vec::new();
            let chain_len = k * k * cblks.len();
            // placed by the plan: `chain_len * dup` coordinates; the
            // `dup` replicas share the leading replica's schedule
            let coords = &plan.chains[mb].coords;
            ensure!(
                coords.len() == chain_len * dup,
                "chain {mb}: plan placed {} tiles, stage needs {}",
                coords.len(),
                chain_len * dup
            );
            let mut ci = 0usize;
            for kr in 0..k {
                for kc in 0..k {
                    for (cb, &(c_lo, c_hi)) in cblks.iter().enumerate() {
                        let rows = c_hi - c_lo;
                        // extract [rows][cols] block, c-major:
                        // tile_w[c'][m'] = W[m_lo+m'][c_lo+c'][kr][kc]
                        let tw = if self.skeleton {
                            Vec::new()
                        } else {
                            let mut tw = vec![0i8; rows * cols];
                            for cpr in 0..rows {
                                let c = c_lo + cpr;
                                let trow = &mut tw[cpr * cols..(cpr + 1) * cols];
                                for (mpr, t) in trow.iter_mut().enumerate() {
                                    let m = m_lo + mpr;
                                    *t = w[((m * c_in + c) * k + kr) * k + kc];
                                }
                            }
                            tw
                        };
                        let role = ConvRole {
                            kr,
                            kc,
                            cb,
                            is_chain_start: ci == 0,
                            is_row_end: kc == k - 1 && cb == cblks.len() - 1,
                            is_last: kr == k - 1 && kc == k - 1 && cb == cblks.len() - 1,
                            is_row_head: kc == 0 && cb == 0 && kr > 0,
                        };
                        let schedule = conv_tile_schedule(&g, &role, relu);
                        let shift_step = if rows <= 64 {
                            64
                        } else if rows <= 128 {
                            128
                        } else {
                            0
                        };
                        tiles.push(ConvTile {
                            kr,
                            kc,
                            cb,
                            coord: coords[ci],
                            rows,
                            cols,
                            weights: tw,
                            schedule,
                            rifm: RifmConfig {
                                channels: rows,
                                forward: ci + 1 < chain_len,
                                shortcut: false,
                                shift_step,
                            },
                            is_chain_start: role.is_chain_start,
                            is_row_end: role.is_row_end,
                            is_last: role.is_last,
                            is_row_head: role.is_row_head,
                        });
                        ci += 1;
                    }
                }
            }
            chains.push(ConvChain {
                mblock: mb,
                m_lo,
                m_hi,
                tiles,
            });
        }
        Ok(ConvStage {
            in_shape,
            out_shape,
            k,
            stride,
            padding,
            relu,
            shift,
            cblocks: cblks.len(),
            mblocks: mblks.len(),
            chains,
            fused_pool,
            dup,
        })
    }

    fn build_fc_stage(
        &self,
        in_features: usize,
        out_features: usize,
        relu: bool,
        shift: u32,
        w: &[i8], // [out][in]
        plan: &FcPlan,
    ) -> Result<FcStage> {
        let rblks = Self::blocks(in_features, self.arch.n_c);
        let cblks = Self::blocks(out_features, self.arch.n_m);
        ensure!(
            plan.columns.len() == cblks.len()
                && plan.columns.iter().all(|c| c.coords.len() == rblks.len()),
            "fc stage needs {} columns of {} tiles each",
            cblks.len(),
            rblks.len()
        );
        let mut columns = Vec::new();
        for (cb, &(o_lo, o_hi)) in cblks.iter().enumerate() {
            let cols = o_hi - o_lo;
            let coords = &plan.columns[cb].coords;
            let mut tiles = Vec::new();
            for (rb, &(i_lo, i_hi)) in rblks.iter().enumerate() {
                let rows = i_hi - i_lo;
                // tile_w[i'][o'] = W[o_lo+o'][i_lo+i']
                let tw = if self.skeleton {
                    Vec::new()
                } else {
                    let mut tw = vec![0i8; rows * cols];
                    for ipr in 0..rows {
                        for opr in 0..cols {
                            tw[ipr * cols + opr] =
                                w[(o_lo + opr) * in_features + (i_lo + ipr)];
                        }
                    }
                    tw
                };
                tiles.push(FcTile {
                    rblock: rb,
                    coord: coords[rb],
                    rows,
                    cols,
                    weights: tw,
                    schedule: fc_tile_schedule(rb, rblks.len(), relu),
                    rifm: RifmConfig {
                        channels: rows,
                        forward: rb + 1 < rblks.len(),
                        shortcut: false,
                        shift_step: 0,
                    },
                });
            }
            columns.push(FcColumn {
                cblock: cb,
                c_lo: o_lo,
                c_hi: o_hi,
                tiles,
            });
        }
        Ok(FcStage {
            in_features,
            out_features,
            relu,
            shift,
            rblocks: rblks.len(),
            cblocks: cblks.len(),
            columns,
        })
    }

    fn build_projection_stage(
        &self,
        src_shape: TensorShape,
        proj: &Projection,
        shift: u32,
        w: &[i8], // [M][C]
        plan: &ConvPlan,
    ) -> Result<ConvStage> {
        // A 1x1 conv: reuse the conv builder with K = 1; expand the
        // [M][C] weight layout to [M][C][1][1] (identical memory).
        let out_shape = proj
            .out_shape(src_shape)
            .context("projection output shape")?;
        self.build_conv_stage(
            src_shape,
            out_shape,
            proj.out_ch,
            1,
            proj.stride,
            0,
            false, // linear: activation happens after the residual add
            shift,
            w,
            None,
            plan,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::noc::chain_is_local;

    #[test]
    fn conv_tile_count_matches_formula() {
        // Section III-B: K² x ⌈C/Nc⌉ x ⌈M/Nm⌉ tiles.
        let net = crate::model::NetworkBuilder::new("t", TensorShape::new(300, 8, 8))
            .conv(300, 3, 1, 1)
            .build();
        let p = Compiler::default().compile(&net).unwrap();
        // ⌈300/256⌉ = 2 both ways: 9 * 2 * 2 = 36
        assert_eq!(p.total_tiles, 36);
    }

    #[test]
    fn fc_tile_count_matches_formula() {
        // Section III-A: ⌈Cin/Nc⌉ x ⌈Cout/Nm⌉.
        let net = crate::model::NetworkBuilder::new("t", TensorShape::new(1000, 1, 1))
            .fc_logits(600)
            .build();
        let p = Compiler::default().compile(&net).unwrap();
        // ⌈1000/256⌉ = 4, ⌈600/256⌉ = 3 -> 12 tiles
        assert_eq!(p.total_tiles, 12);
    }

    #[test]
    fn pool_after_conv_is_fused() {
        let net = crate::model::NetworkBuilder::new("t", TensorShape::new(3, 8, 8))
            .conv(8, 3, 1, 1)
            .max_pool(2, 2)
            .build();
        let p = Compiler::default().compile(&net).unwrap();
        assert_eq!(p.stages.len(), 1);
        match &p.stages[0].kind {
            StageKind::Conv(c) => {
                assert_eq!(
                    c.fused_pool,
                    Some(PoolSpec {
                        max: true,
                        kernel: 2,
                        stride: 2
                    })
                );
                assert_eq!(c.dup, 1, "block reuse adds no tiles");
            }
            _ => panic!("conv stage expected"),
        }
    }

    #[test]
    fn weight_duplication_multiplies_tiles() {
        let net = crate::model::NetworkBuilder::new("t", TensorShape::new(3, 8, 8))
            .conv(8, 3, 1, 1)
            .max_pool(2, 2)
            .build();
        let mut arch = ArchConfig::default();
        arch.pooling = PoolingScheme::WeightDuplication;
        let p = Compiler::new(arch).compile(&net).unwrap();
        // 9 tiles x Kp² = 36
        assert_eq!(p.total_tiles, 36);
    }

    #[test]
    fn chains_are_mesh_local_and_fit_hardware() {
        let net = zoo::tiny_cnn();
        let p = Compiler::default().compile(&net).unwrap();
        assert!(p.schedules_fit_hardware());
        for stage in &p.stages {
            if let StageKind::Conv(c) = &stage.kind {
                for ch in &c.chains {
                    let coords: Vec<_> = ch.tiles.iter().map(|t| t.coord).collect();
                    assert!(chain_is_local(&coords), "{}: chain not local", stage.name);
                }
            }
        }
    }

    #[test]
    fn chip_partitioning_at_240_tiles() {
        let net = zoo::vgg16_imagenet();
        let p = Compiler::default().compile(&net).unwrap();
        assert!(p.total_tiles > 240, "VGG-16 spans multiple chips");
        assert_eq!(p.chips, p.total_tiles.div_ceil(240));
    }

    #[test]
    fn resnet_projection_gets_tiles() {
        let net = zoo::resnet18_cifar();
        let p = Compiler::default().compile(&net).unwrap();
        let res_with_proj = p
            .stages
            .iter()
            .filter(
                |s| matches!(&s.kind, StageKind::Res(r) if r.proj.is_some()),
            )
            .count();
        assert_eq!(res_with_proj, 3, "three downsampling blocks in ResNet-18");
        // every projection is a K=1 conv stage
        for s in &p.stages {
            if let StageKind::Res(r) = &s.kind {
                if let Some(pr) = &r.proj {
                    assert_eq!(pr.k, 1);
                    assert!(!pr.relu);
                }
            }
        }
    }

    #[test]
    fn sync_waterfill_respects_chip_budget() {
        let net = zoo::vgg11_cifar();
        let base = Compiler::default().compile(&net).unwrap();
        let filled = Compiler::new(ArchConfig::table4(5)).compile(&net).unwrap();
        assert!(filled.total_tiles > base.total_tiles);
        assert!(filled.total_tiles <= 5 * 240, "budget exceeded: {}", filled.total_tiles);
        assert_eq!(filled.chips, 5);
        // the bottleneck conv must have been duplicated
        let max_dup = filled
            .stages
            .iter()
            .filter_map(|s| match &s.kind {
                StageKind::Conv(c) => Some(c.dup),
                _ => None,
            })
            .max()
            .unwrap();
        assert!(max_dup > 1, "water-fill did nothing");
    }

    #[test]
    fn sync_waterfill_equalizes_periods() {
        // after water-filling, the spread between the slowest and
        // fastest duplicable conv stage must shrink
        let net = zoo::vgg11_cifar();
        let spread = |p: &crate::coordinator::program::Program| {
            let periods: Vec<u64> = p
                .stages
                .iter()
                .filter_map(|s| match &s.kind {
                    StageKind::Conv(c) => {
                        let g = ConvGeometry::new(c.k, c.stride, c.padding, c.in_shape.h, c.in_shape.w);
                        Some((g.stream_slots() as u64).div_ceil(c.dup as u64))
                    }
                    _ => None,
                })
                .collect();
            let max = *periods.iter().max().unwrap();
            let min = *periods.iter().min().unwrap();
            max as f64 / min as f64
        };
        let base = Compiler::default().compile(&net).unwrap();
        let filled = Compiler::new(ArchConfig::table4(5)).compile(&net).unwrap();
        assert!(spread(&filled) < spread(&base));
    }

    #[test]
    fn resnet_res_stages_inherit_duplication() {
        let net = zoo::resnet18_cifar();
        let p = Compiler::new(ArchConfig::table4(6)).compile(&net).unwrap();
        for s in &p.stages {
            if let StageKind::Res(r) = &s.kind {
                assert!(r.dup >= 1);
                if let Some(proj) = &r.proj {
                    // the junction never runs faster than its projection
                    assert!(r.dup <= proj.dup);
                }
            }
        }
        // at a 6-chip budget at least one res junction runs duplicated
        assert!(
            p.stages.iter().any(|s| matches!(&s.kind, StageKind::Res(r) if r.dup > 1)),
            "no res stage duplicated"
        );
    }

    #[test]
    fn undersized_budget_degrades_to_minimum_mapping() {
        // a 1-chip budget below the Section III-B minimum leaves every
        // dup at 1 (never fails, never exceeds the minimum mapping)
        let net = zoo::vgg11_cifar();
        let base = Compiler::default().compile(&net).unwrap();
        let p = Compiler::new(ArchConfig::table4(0)).compile(&net).unwrap();
        assert_eq!(p.total_tiles, base.total_tiles);
    }

    #[test]
    fn chip_aligned_chains_never_straddle() {
        let net = zoo::vgg16_imagenet();
        let mut arch = ArchConfig::default();
        arch.chip_aligned_chains = true;
        let p = Compiler::new(arch).compile_analysis(&net).unwrap();
        for stage in &p.stages {
            if let StageKind::Conv(c) = &stage.kind {
                for ch in &c.chains {
                    let chips: std::collections::BTreeSet<usize> =
                        ch.tiles.iter().map(|t| t.coord.chip).collect();
                    if ch.tiles.len() <= 240 {
                        assert_eq!(chips.len(), 1, "{} chain straddles", stage.name);
                    }
                }
            }
        }
        // padding is bounded: < one chip of waste
        let base = Compiler::default().compile_analysis(&net).unwrap();
        assert!(p.total_tiles - base.total_tiles < 360);
    }

    #[test]
    fn conv_weights_land_in_correct_tiles() {
        use crate::model::refcompute::Weights;
        let net = crate::model::NetworkBuilder::new("t", TensorShape::new(5, 6, 6))
            .conv(7, 3, 1, 1)
            .build();
        let weights = Weights::random(&net, 9).unwrap();
        let p = Compiler::default()
            .compile_with_weights(&net, &weights)
            .unwrap();
        let w = weights.per_layer[0].as_slice(); // [M=7][C=5][3][3]
        match &p.stages[0].kind {
            StageKind::Conv(c) => {
                assert_eq!(c.chains.len(), 1);
                for t in &c.chains[0].tiles {
                    for cc in 0..t.rows {
                        for m in 0..t.cols {
                            let want = w[((m * 5 + cc) * 3 + t.kr) * 3 + t.kc];
                            assert_eq!(t.weights[cc * t.cols + m], want);
                        }
                    }
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn fc_weights_transposed_correctly() {
        use crate::model::refcompute::Weights;
        let net = crate::model::NetworkBuilder::new("t", TensorShape::new(10, 1, 1))
            .fc_logits(6)
            .build();
        let weights = Weights::random(&net, 11).unwrap();
        let p = Compiler::default()
            .compile_with_weights(&net, &weights)
            .unwrap();
        let w = weights.per_layer[0].as_slice(); // [out=6][in=10]
        match &p.stages[0].kind {
            StageKind::Fc(f) => {
                let t = &f.columns[0].tiles[0];
                for i in 0..10 {
                    for o in 0..6 {
                        assert_eq!(t.weights[i * 6 + o], w[o * 10 + i]);
                    }
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn multiblock_chain_roles() {
        // C=300 -> 2 cblocks; check role flags along the chain.
        let net = crate::model::NetworkBuilder::new("t", TensorShape::new(300, 4, 4))
            .conv(8, 3, 1, 1)
            .build();
        let p = Compiler::default().compile(&net).unwrap();
        match &p.stages[0].kind {
            StageKind::Conv(c) => {
                let tiles = &c.chains[0].tiles;
                assert_eq!(tiles.len(), 18);
                assert!(tiles[0].is_chain_start);
                // row end = kc==2 && cb==1: positions 5, 11, 17
                assert!(tiles[5].is_row_end && !tiles[5].is_last);
                assert!(tiles[17].is_row_end && tiles[17].is_last);
                // row heads at kr>0, kc==0, cb==0: positions 6, 12
                assert!(tiles[6].is_row_head);
                assert!(tiles[12].is_row_head);
                assert!(!tiles[0].is_row_head);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn small_channel_layers_get_shift_config() {
        let net = zoo::tiny_cnn(); // first conv has C=3
        let p = Compiler::default().compile(&net).unwrap();
        match &p.stages[0].kind {
            StageKind::Conv(c) => {
                assert_eq!(c.chains[0].tiles[0].rifm.shift_step, 64);
            }
            _ => panic!(),
        }
    }

    /// The phase split's core contract: the materialized program pins
    /// every tile to exactly the coordinate its plan placed, and the
    /// plan's totals are the program's totals.
    #[test]
    fn materialized_program_matches_its_plan() {
        for (net, arch) in [
            (zoo::tiny_cnn(), ArchConfig::default()),
            (zoo::tiny_resnet(), ArchConfig::tiny(4)),
            (zoo::resnet18_cifar(), ArchConfig::table4(6)),
        ] {
            let compiler = Compiler::new(arch);
            let plan = compiler.plan(&net).unwrap();
            let p = compiler.compile_analysis(&net).unwrap();
            assert_eq!(p.total_tiles, plan.total_tiles, "{}", net.name);
            assert_eq!(p.chips, plan.chips, "{}", net.name);
            for stage in &p.stages {
                match (&stage.kind, &plan.layers[stage.layer]) {
                    (StageKind::Conv(c), LayerPlan::Conv(cp)) => {
                        for (ch, chp) in c.chains.iter().zip(&cp.chains) {
                            for (t, want) in ch.tiles.iter().zip(&chp.coords) {
                                assert_eq!(t.coord, *want, "{} {}", net.name, stage.name);
                            }
                        }
                    }
                    (StageKind::Fc(f), LayerPlan::Fc(fp)) => {
                        for (col, colp) in f.columns.iter().zip(&fp.columns) {
                            for (t, want) in col.tiles.iter().zip(&colp.coords) {
                                assert_eq!(t.coord, *want, "{} {}", net.name, stage.name);
                            }
                        }
                    }
                    (StageKind::Res(r), lp) => {
                        if let (Some(pr), LayerPlan::Conv(cp)) = (&r.proj, lp) {
                            for (ch, chp) in pr.chains.iter().zip(&cp.chains) {
                                for (t, want) in ch.tiles.iter().zip(&chp.coords) {
                                    assert_eq!(t.coord, *want, "{} {}", net.name, stage.name);
                                }
                            }
                        }
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn masked_compile_relocates_but_preserves_weights_and_schedules() {
        use crate::model::refcompute::Weights;
        let net = zoo::tiny_cnn();
        let compiler = Compiler::default();
        let weights = Weights::random(&net, compiler.weight_seed).unwrap();
        let base = compiler.compile_with_weights(&net, &weights).unwrap();
        // ban the first placed tile; the masked program must avoid it
        let bad = match &base.stages[0].kind {
            StageKind::Conv(c) => c.chains[0].tiles[0].coord,
            _ => panic!("tiny_cnn starts with a conv"),
        };
        let mut mask = TileMask::new();
        mask.ban_tile(bad);
        let masked = compiler
            .compile_with_weights_masked(&net, &weights, &mask)
            .unwrap();
        for (a, b) in masked.stages.iter().zip(&base.stages) {
            if let (StageKind::Conv(ca), StageKind::Conv(cb)) = (&a.kind, &b.kind) {
                for (cha, chb) in ca.chains.iter().zip(&cb.chains) {
                    for (x, y) in cha.tiles.iter().zip(&chb.tiles) {
                        assert_ne!(x.coord, bad, "masked tile still in use");
                        // placement moved; weights and schedules did not
                        assert_eq!(x.weights, y.weights);
                        assert_eq!(x.schedule, y.schedule);
                    }
                }
            }
        }
        assert!(masked.total_tiles >= base.total_tiles);
    }

    #[test]
    fn materialize_rejects_a_foreign_plan() {
        let net = zoo::tiny_cnn();
        let other = Compiler::new(ArchConfig::tiny(4)).plan(&net).unwrap();
        let weights = Weights::random(&net, 1).unwrap();
        assert!(Compiler::default()
            .materialize(&net, &weights, &other)
            .is_err());
    }

    #[test]
    fn column_major_placement_changes_coords_not_structure() {
        let net = zoo::tiny_cnn();
        let base = Compiler::default().compile(&net).unwrap();
        let mut arch = ArchConfig::default();
        arch.placement = Placement::ColumnMajor;
        let cm = Compiler::new(arch).compile(&net).unwrap();
        assert_eq!(cm.total_tiles, base.total_tiles);
        assert_eq!(cm.chips, base.chips);
        assert_eq!(cm.stages.len(), base.stages.len());
        // chains stay mesh-local, but at least one tile moved
        let mut moved = false;
        for (a, b) in cm.stages.iter().zip(&base.stages) {
            if let (StageKind::Conv(ca), StageKind::Conv(cb)) = (&a.kind, &b.kind) {
                for (cha, chb) in ca.chains.iter().zip(&cb.chains) {
                    let coords: Vec<_> = cha.tiles.iter().map(|t| t.coord).collect();
                    assert!(chain_is_local(&coords), "{}: chain not local", a.name);
                    moved |= cha
                        .tiles
                        .iter()
                        .zip(&chb.tiles)
                        .any(|(x, y)| x.coord != y.coord);
                }
            }
        }
        assert!(moved, "column-major must actually relocate tiles");
        // weights and schedules are placement-independent
        for (a, b) in cm.stages.iter().zip(&base.stages) {
            if let (StageKind::Conv(ca), StageKind::Conv(cb)) = (&a.kind, &b.kind) {
                for (cha, chb) in ca.chains.iter().zip(&cb.chains) {
                    for (x, y) in cha.tiles.iter().zip(&chb.tiles) {
                        assert_eq!(x.weights, y.weights);
                        assert_eq!(x.schedule, y.schedule);
                    }
                }
            }
        }
    }
}
