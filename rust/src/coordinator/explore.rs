//! The cost-model-driven mapping explorer.
//!
//! The paper's headline system claim is that Domino's distributed NoC
//! scheduling "attains mapping flexibility"; this module makes that
//! flexibility a searchable, first-class object. It enumerates
//! candidate [`MappingChoice`]s (pooling scheme × placement strategy ×
//! mesh shape × chip alignment, within bounds), scores each one purely
//! analytically — no cycle simulation:
//!
//! * **timing** from `perfmodel::estimate` (one-image latency, the
//!   pipelined steady-state period, images/s);
//! * **energy per image** from the Table III `energy` model over the
//!   estimate's event counters;
//! * **NoC feasibility** from the `noc::flit` static link analysis
//!   (worst offered link load on either router network must fit the
//!   40 Gb/s links) plus the 128-entry schedule-table bound;
//!
//! and returns a table ranked per [`Objective`] (latency,
//! energy-per-image, or tile count), feasible candidates first. A
//! candidate's [`Candidate::arch`] drops straight into
//! `Compiler::new(..)`, the serving registry, or — through
//! `serve::api::MappingSpec` — a remote `Load` request
//! (`domino map explore <model>` / `domino client load --placement …`).

use anyhow::Result;

use crate::coordinator::mapper::{ArchConfig, Compiler, PoolingScheme};
use crate::coordinator::plan::{Placement, TileMask};
use crate::energy::{energy_of, CimModel};
use crate::model::Network;
use crate::noc::flit;
use crate::perfmodel;

/// One point of the mapping space the explorer searches: the
/// per-model arch knobs. Crossbar geometry (`n_c`/`n_m`), chip size
/// and the `sync_chips` duplication budget come from the base
/// [`ArchConfig`] and are not swept.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MappingChoice {
    pub pooling: PoolingScheme,
    pub placement: Placement,
    pub mesh_cols: usize,
    pub chip_aligned: bool,
}

impl MappingChoice {
    /// The mapping knobs a base config currently has.
    pub fn of_arch(a: &ArchConfig) -> Self {
        Self {
            pooling: a.pooling,
            placement: a.placement,
            mesh_cols: a.mesh_cols,
            chip_aligned: a.chip_aligned_chains,
        }
    }

    /// Apply this choice onto a base config.
    pub fn apply(&self, mut base: ArchConfig) -> ArchConfig {
        base.pooling = self.pooling;
        base.placement = self.placement;
        base.mesh_cols = self.mesh_cols;
        base.chip_aligned_chains = self.chip_aligned;
        base
    }
}

/// Ranking objective for [`explore`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Minimize one-image latency (ties: period, then tiles).
    Latency,
    /// Minimize analytic energy per image (ties: tiles).
    Energy,
    /// Minimize allocated tiles (ties: latency).
    Tiles,
}

impl Objective {
    pub fn name(self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Energy => "energy",
            Objective::Tiles => "tiles",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "latency" => Ok(Objective::Latency),
            "energy" | "energy-per-image" => Ok(Objective::Energy),
            "tiles" | "tile-count" => Ok(Objective::Tiles),
            other => anyhow::bail!(
                "unknown objective {other:?} (use \"latency\", \"energy\" or \"tiles\")"
            ),
        }
    }
}

/// Sweep bounds for [`enumerate`]. Defaults: both pooling schemes,
/// both placement strategies, mesh widths {12, 16, 20}, chip alignment
/// on and off — 24 candidates.
#[derive(Clone, Debug)]
pub struct ExploreBounds {
    pub poolings: Vec<PoolingScheme>,
    pub placements: Vec<Placement>,
    pub mesh_cols: Vec<usize>,
    pub chip_aligned: Vec<bool>,
}

impl Default for ExploreBounds {
    fn default() -> Self {
        Self {
            poolings: PoolingScheme::ALL.to_vec(),
            placements: Placement::ALL.to_vec(),
            mesh_cols: vec![12, 16, 20],
            chip_aligned: vec![false, true],
        }
    }
}

/// The analytic measurement of one compiled program — the single
/// source of truth shared by the explorer's candidate scoring and the
/// observability plane's `serve::api::MappingDesc`, so the ranked
/// table and `ModelInfo` can never disagree on the math.
#[derive(Clone, Copy, Debug)]
pub struct ProgramScore {
    pub tiles: usize,
    pub chips: usize,
    /// One-image latency (layers back-to-back), cycles.
    pub latency_cycles: u64,
    /// Pipelined steady-state period, cycles.
    pub period_cycles: u64,
    pub images_per_s: f64,
    /// Analytic energy per image (generic SRAM CIM model), joules.
    pub energy_per_image_j: f64,
    /// Worst offered link load across both router networks
    /// (1.0 = a saturated 40 Gb/s link).
    pub worst_link_utilization: f64,
    /// Link loads fit the dual-router mesh and every schedule fits the
    /// 128-entry hardware table.
    pub feasible: bool,
}

/// Measure a compiled program analytically (weight-independent, so
/// skeleton programs work): perfmodel timing, Table III energy per
/// image, and the static worst-link NoC load.
pub fn analyze(program: &crate::coordinator::Program) -> Result<ProgramScore> {
    let est = perfmodel::estimate(program)?;
    let report = flit::dual_router_report(&flit::program_flows(program));
    let worst = report
        .rifm
        .peak_utilization
        .max(report.rofm.peak_utilization);
    let energy = energy_of(&est.counters, &CimModel::generic_sram()).total();
    Ok(ProgramScore {
        tiles: program.total_tiles,
        chips: program.chips,
        latency_cycles: est.latency_cycles,
        period_cycles: est.period_cycles,
        images_per_s: est.images_per_s(),
        energy_per_image_j: energy,
        worst_link_utilization: worst,
        feasible: worst <= 1.0 + 1e-9 && program.schedules_fit_hardware(),
    })
}

/// One scored candidate mapping.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub choice: MappingChoice,
    /// The base config with `choice` applied — ready for
    /// `Compiler::new` or a registry load.
    pub arch: ArchConfig,
    pub tiles: usize,
    pub chips: usize,
    /// One-image latency (layers back-to-back), cycles.
    pub latency_cycles: u64,
    /// Pipelined steady-state period, cycles.
    pub period_cycles: u64,
    pub images_per_s: f64,
    /// Analytic energy per image (generic SRAM CIM model), joules.
    pub energy_per_image_j: f64,
    /// Worst offered link load across both router networks
    /// (1.0 = a saturated 40 Gb/s link).
    pub worst_link_utilization: f64,
    /// Link loads fit the dual-router mesh and every schedule fits the
    /// 128-entry hardware table.
    pub feasible: bool,
}

/// Enumerate the candidate choices within `bounds`, dropping mesh
/// widths the base chip cannot hold.
pub fn enumerate(base: &ArchConfig, bounds: &ExploreBounds) -> Vec<MappingChoice> {
    let mut out = Vec::new();
    for &pooling in &bounds.poolings {
        for &placement in &bounds.placements {
            for &mesh_cols in &bounds.mesh_cols {
                if mesh_cols == 0 || mesh_cols > base.tiles_per_chip {
                    continue;
                }
                for &chip_aligned in &bounds.chip_aligned {
                    out.push(MappingChoice {
                        pooling,
                        placement,
                        mesh_cols,
                        chip_aligned,
                    });
                }
            }
        }
    }
    out
}

/// Score one choice analytically (skeleton compile — no weights, no
/// cycle simulation).
pub fn score(net: &Network, base: &ArchConfig, choice: MappingChoice) -> Result<Candidate> {
    let arch = choice.apply(*base);
    let program = Compiler::new(arch).compile_analysis(net)?;
    let s = analyze(&program)?;
    Ok(Candidate {
        choice,
        arch,
        tiles: s.tiles,
        chips: s.chips,
        latency_cycles: s.latency_cycles,
        period_cycles: s.period_cycles,
        images_per_s: s.images_per_s,
        energy_per_image_j: s.energy_per_image_j,
        worst_link_utilization: s.worst_link_utilization,
        feasible: s.feasible,
    })
}

/// Rank candidates in place: feasible first, then by the objective
/// (stable, so the deterministic enumeration order breaks exact ties).
pub fn rank(candidates: &mut [Candidate], objective: Objective) {
    candidates.sort_by(|a, b| {
        b.feasible.cmp(&a.feasible).then_with(|| match objective {
            Objective::Latency => a
                .latency_cycles
                .cmp(&b.latency_cycles)
                .then_with(|| a.period_cycles.cmp(&b.period_cycles))
                .then_with(|| a.tiles.cmp(&b.tiles)),
            Objective::Energy => a
                .energy_per_image_j
                .total_cmp(&b.energy_per_image_j)
                .then_with(|| a.tiles.cmp(&b.tiles)),
            Objective::Tiles => a
                .tiles
                .cmp(&b.tiles)
                .then_with(|| a.latency_cycles.cmp(&b.latency_cycles)),
        })
    });
}

/// Enumerate, score and rank: the full explorer pass.
pub fn explore(
    net: &Network,
    base: &ArchConfig,
    bounds: &ExploreBounds,
    objective: Objective,
) -> Result<Vec<Candidate>> {
    let mut candidates = enumerate(base, bounds)
        .into_iter()
        .map(|c| score(net, base, c))
        .collect::<Result<Vec<_>>>()?;
    rank(&mut candidates, objective);
    Ok(candidates)
}

/// [`score`] with a [`TileMask`] feasibility constraint: the candidate
/// is compiled around the masked tiles/links, so its tile count,
/// timing and energy include the routing-around penalty.
pub fn score_masked(
    net: &Network,
    base: &ArchConfig,
    choice: MappingChoice,
    mask: &TileMask,
) -> Result<Candidate> {
    let arch = choice.apply(*base);
    let program = Compiler::new(arch).compile_analysis_masked(net, mask)?;
    let s = analyze(&program)?;
    Ok(Candidate {
        choice,
        arch,
        tiles: s.tiles,
        chips: s.chips,
        latency_cycles: s.latency_cycles,
        period_cycles: s.period_cycles,
        images_per_s: s.images_per_s,
        energy_per_image_j: s.energy_per_image_j,
        worst_link_utilization: s.worst_link_utilization,
        feasible: s.feasible,
    })
}

/// [`explore`] under a [`TileMask`]: masked resources are a hard
/// feasibility constraint. A candidate whose masked placement cannot
/// converge is dropped from the table (not an error — the rest of the
/// sweep still ranks); every returned candidate's scores already
/// include its routing-around penalty. An empty mask reproduces
/// [`explore`] exactly.
pub fn explore_masked(
    net: &Network,
    base: &ArchConfig,
    bounds: &ExploreBounds,
    objective: Objective,
    mask: &TileMask,
) -> Result<Vec<Candidate>> {
    if mask.is_empty() {
        return explore(net, base, bounds, objective);
    }
    let mut candidates = Vec::new();
    for c in enumerate(base, bounds) {
        if let Ok(cand) = score_masked(net, base, c, mask) {
            candidates.push(cand);
        }
    }
    rank(&mut candidates, objective);
    Ok(candidates)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn enumeration_respects_bounds_and_chip_size() {
        let base = ArchConfig::default();
        let all = enumerate(&base, &ExploreBounds::default());
        assert_eq!(all.len(), 2 * 2 * 3 * 2);
        // a mesh wider than the chip is dropped, not scored
        let mut bounds = ExploreBounds::default();
        bounds.mesh_cols = vec![16, 10_000];
        assert_eq!(enumerate(&base, &bounds).len(), 2 * 2 * 2);
    }

    #[test]
    fn explorer_ranks_tiny_cnn_per_objective() {
        let net = zoo::tiny_cnn();
        let base = ArchConfig::default();
        for objective in [Objective::Latency, Objective::Energy, Objective::Tiles] {
            let cands = explore(&net, &base, &ExploreBounds::default(), objective).unwrap();
            assert!(!cands.is_empty());
            assert!(cands[0].feasible, "tiny-cnn must have a feasible mapping");
            for w in cands.windows(2) {
                if !(w[0].feasible && w[1].feasible) {
                    // infeasible candidates sort after all feasible ones
                    assert!(w[0].feasible || !w[1].feasible);
                    continue;
                }
                match objective {
                    Objective::Latency => {
                        assert!(w[0].latency_cycles <= w[1].latency_cycles)
                    }
                    Objective::Energy => {
                        assert!(w[0].energy_per_image_j <= w[1].energy_per_image_j)
                    }
                    Objective::Tiles => assert!(w[0].tiles <= w[1].tiles),
                }
            }
        }
    }

    #[test]
    fn candidate_tiles_match_a_real_compile() {
        let net = zoo::tiny_resnet();
        let base = ArchConfig::default();
        for cand in explore(&net, &base, &ExploreBounds::default(), Objective::Tiles).unwrap() {
            let p = Compiler::new(cand.arch).compile_analysis(&net).unwrap();
            assert_eq!(p.total_tiles, cand.tiles, "{:?}", cand.choice);
            assert_eq!(p.chips, cand.chips, "{:?}", cand.choice);
        }
    }

    #[test]
    fn weight_duplication_candidates_trade_tiles_for_speed() {
        // on a pooled conv net, the duplication scheme must appear in
        // the sweep with more tiles and a shorter period
        let net = zoo::tiny_cnn();
        let base = ArchConfig::default();
        let cands = explore(&net, &base, &ExploreBounds::default(), Objective::Latency).unwrap();
        let block = cands
            .iter()
            .find(|c| c.choice.pooling == PoolingScheme::BlockReuse)
            .unwrap();
        let dup = cands
            .iter()
            .find(|c| c.choice.pooling == PoolingScheme::WeightDuplication)
            .unwrap();
        assert!(dup.tiles > block.tiles);
        assert!(dup.period_cycles < block.period_cycles);
    }

    #[test]
    fn masked_explore_prices_the_routing_around_penalty() {
        let net = zoo::tiny_cnn();
        let base = ArchConfig::default();
        let free = explore(&net, &base, &ExploreBounds::default(), Objective::Tiles).unwrap();
        // ban the mesh origin on chip 0 — every placement strategy
        // starts there, so every candidate pays a shift
        let mut mask = TileMask::new();
        mask.ban_tile(crate::noc::Coord::new(0, 0, 0));
        let masked = explore_masked(
            &net,
            &base,
            &ExploreBounds::default(),
            Objective::Tiles,
            &mask,
        )
        .unwrap();
        assert!(!masked.is_empty());
        assert!(
            masked[0].tiles >= free[0].tiles,
            "masking can never shrink the best mapping"
        );
        // empty mask is exactly the unmasked sweep
        let same = explore_masked(
            &net,
            &base,
            &ExploreBounds::default(),
            Objective::Tiles,
            &TileMask::new(),
        )
        .unwrap();
        assert_eq!(same.len(), free.len());
        assert_eq!(same[0].tiles, free[0].tiles);
    }

    #[test]
    fn choice_roundtrips_through_arch() {
        let base = ArchConfig::default();
        for choice in enumerate(&base, &ExploreBounds::default()) {
            assert_eq!(MappingChoice::of_arch(&choice.apply(base)), choice);
        }
    }
}
