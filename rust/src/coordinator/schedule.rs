//! Periodic instruction-schedule generation (paper Section II-C).
//!
//! "After cycle-accurate analyses and mathematical derivation,
//! instructions reveal an attribute of periodicity."
//!
//! ## Stream and period model
//!
//! The IFM of a conv layer streams in *padded raster order*: rows
//! `py ∈ [-P, H-1+P]`, and within each row `Wp = W + 2P` pixel slots
//! (`u ∈ [0, Wp)`, `px = u - P`; padding slots carry zeros). One pixel
//! slot costs **two** instruction cycles — sub-cycle A moves/loads the
//! IFM beat and fires the PE, sub-cycle B moves/accumulates the partial
//! sum (a 256-lane i32 psum beat is 8192 b, two 4000 b link beats at
//! 40 Gb/s per 10 MHz step — the physical reason for the factor 2).
//! Hence the steady-state period of a stride-1 conv tile is
//! `p = 2(P + W)` cycles per kernel row, exactly the paper's formula
//! (the paper counts one padding margin per row period; the other
//! margin's slots are the same table entries wrapped around).
//!
//! For `S_c = stride ≠ 1` the same table is generated over
//! `stride` consecutive rows (`stride · Wp` slots) with invalid slots
//! *shielded* ("the compiler will shield certain bits in control words
//! to skip some actions"), and for pooling rows the last tile runs
//! M-type entries with period `2·S_p`.
//!
//! The `Schedule` tables here are expressed at pixel-slot granularity
//! (one `Instr` per slot = per 2 cycles); `Schedule::compressed_len`
//! run-length-compresses them into the 128-entry hardware table.
//!
//! ## Which tile does what (conv chain, output position (oy, ox))
//!
//! * every tile: PE-MACs the streamed pixel against its stationary
//!   block; valid when its kernel offset (kr, kc) aligns: `u = kc +
//!   ox·s` and row `py = kr - P + oy·s`.
//! * chain-start tile (kr=0, kc=0, cb=0): starts a psum beat (`Acc`,
//!   rx = {PE}), transmits (`AccOut`).
//! * interior tiles: `AccOut` with rx = {chain-in, PE}.
//! * kernel-row-end tiles (kc=K-1, cb=Cb-1, kr<K-1): their output is a
//!   *group-sum* `U_g(kr)`; it is transmitted to the next kernel row's
//!   head tile and queued there (`Buffer=Push` on arrival).
//! * kernel-row-head tiles (kc=0, cb=0, kr>0): `Buffer=Pop` exactly one
//!   row period after the Push — the popped group-sum seeds the row's
//!   accumulation so sums keep moving (computing-on-the-move).
//! * the last tile (kr=K-1 row end): M-type — `Act`/`Quant` (+ fused
//!   `Cmp`/`Mul` pooling under the block-reuse scheme) and OFM hand-off
//!   (`Tx=NextLayer`).

use crate::coordinator::isa::{
    BufferOp, COpcode, Func, Instr, MOpcode, RxCtrl, RxSource, Schedule, TxCtrl,
};
use crate::model::conv_out;

/// Cycles per pixel slot (see module docs: IFM sub-cycle + psum
/// sub-cycle).
pub const CYCLES_PER_SLOT: usize = 2;

/// Geometry of a conv stage, shared by schedule generation and the
/// engine's slot arithmetic.
#[derive(Clone, Copy, Debug)]
pub struct ConvGeometry {
    pub k: usize,
    pub stride: usize,
    pub padding: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_h: usize,
    pub out_w: usize,
}

impl ConvGeometry {
    pub fn new(k: usize, stride: usize, padding: usize, in_h: usize, in_w: usize) -> Self {
        let out_h = conv_out(in_h, k, stride, padding).expect("conv geometry");
        let out_w = conv_out(in_w, k, stride, padding).expect("conv geometry");
        Self {
            k,
            stride,
            padding,
            in_h,
            in_w,
            out_h,
            out_w,
        }
    }

    /// Padded row width in pixel slots.
    pub fn wp(&self) -> usize {
        self.in_w + 2 * self.padding
    }

    /// Padded stream height (rows -P .. H-1+P).
    pub fn hp(&self) -> usize {
        self.in_h + 2 * self.padding
    }

    /// Total pixel slots in one image's stream.
    pub fn stream_slots(&self) -> usize {
        self.wp() * self.hp()
    }

    /// The paper's quoted period formula (`p = 2(P + W)` for stride 1,
    /// Section II-C) — the paper counts one padding margin per row; our
    /// stream counts both sides, so the implemented period is
    /// [`Self::period_cycles`] = `2(W + 2P)` and we report both.
    pub fn paper_period_cycles(&self) -> usize {
        CYCLES_PER_SLOT * (self.padding + self.in_w)
    }

    /// Actual table period in cycles.
    pub fn period_cycles(&self) -> usize {
        CYCLES_PER_SLOT * self.period_slots()
    }

    /// Table period in pixel slots (covers `stride` rows so y-shielding
    /// is expressible).
    pub fn period_slots(&self) -> usize {
        self.wp() * self.stride
    }

    /// For padded slot `u` within a row, the output column this slot's
    /// MAC contributes to at kernel column `kc` — if the window aligns.
    pub fn out_col(&self, u: usize, kc: usize) -> Option<usize> {
        let d = u.checked_sub(kc)?;
        if d % self.stride != 0 {
            return None;
        }
        let ox = d / self.stride;
        (ox < self.out_w).then_some(ox)
    }

    /// For padded row index `pr` (0-based from the top of the padded
    /// stream), the output row at kernel row `kr` — if aligned.
    pub fn out_row(&self, pr: usize, kr: usize) -> Option<usize> {
        let d = pr.checked_sub(kr)?;
        if d % self.stride != 0 {
            return None;
        }
        let oy = d / self.stride;
        (oy < self.out_h).then_some(oy)
    }
}

/// Role of a conv tile within its chain (mirrors `program::ConvTile`
/// flags).
#[derive(Clone, Copy, Debug)]
pub struct ConvRole {
    pub kr: usize,
    pub kc: usize,
    pub cb: usize,
    pub is_chain_start: bool,
    pub is_row_end: bool,
    pub is_last: bool,
    pub is_row_head: bool,
}

/// Generate the periodic schedule for one conv tile.
///
/// Entries are per pixel slot; the table covers `stride` padded rows
/// (`stride * Wp` slots) so stride shielding in both x and y is
/// expressed. Slot 0 corresponds to the start of a padded row with
/// `(row - kr) % stride == 0` (the engine and hardware counter align on
/// packet arrival).
pub fn conv_tile_schedule(g: &ConvGeometry, role: &ConvRole, relu: bool) -> Schedule {
    let wp = g.wp();
    let mut table = Vec::with_capacity(wp * g.stride);
    let chain_rx = if role.is_chain_start {
        RxCtrl::NONE.with(RxSource::Pe)
    } else {
        RxCtrl::NONE.with(RxSource::West).with(RxSource::Pe)
    };
    for rowmod in 0..g.stride {
        // rows where (rowmod == 0) are the rows whose MACs this tile
        // contributes to (aligned with kr).
        let row_valid = rowmod == 0;
        for u in 0..wp {
            let ox = g.out_col(u, role.kc);
            let valid = row_valid && ox.is_some();

            // Buffer ops for row heads: Pop at this tile's own MAC slot
            // (the queued group-sum from the previous kernel row seeds
            // the accumulation). Push slots are marked in a post-pass
            // because arrivals can wrap past the period boundary.
            let buffer = if role.is_row_head && valid {
                BufferOp::Pop
            } else {
                BufferOp::None
            };

            let instr = if role.is_last {
                if valid {
                    Instr::M {
                        rx: chain_rx,
                        func: if relu { Func::Act } else { Func::Quant },
                        tx: TxCtrl::NextLayer,
                        opc: MOpcode::ApplyOut,
                    }
                } else {
                    Instr::M {
                        rx: chain_rx,
                        func: Func::Bp,
                        tx: TxCtrl::None,
                        opc: MOpcode::Apply,
                    }
                }
            } else if valid || buffer != BufferOp::None {
                Instr::C {
                    rx: chain_rx,
                    sum: valid,
                    buffer,
                    tx: if valid { TxCtrl::Chain } else { TxCtrl::None },
                    opc: if valid { COpcode::AccOut } else { COpcode::Nop },
                }
            } else {
                // shielded slot: keep receives, suppress actions
                Instr::C {
                    rx: chain_rx,
                    sum: false,
                    buffer: BufferOp::None,
                    tx: TxCtrl::None,
                    opc: COpcode::Nop,
                }
                .shielded()
            };
            table.push(instr);
        }
    }
    // Post-pass for row heads: mark the Push slot for each group-sum
    // arrival — one hop after the previous row-end emitted, i.e.
    // `u = K + ox·s`, wrapped modulo the period.
    if role.is_row_head {
        let period = table.len();
        for ox in 0..g.out_w {
            let v = (g.k + ox * g.stride) % period;
            if let Instr::C { buffer, .. } = &mut table[v] {
                *buffer = match *buffer {
                    BufferOp::None | BufferOp::Push => BufferOp::Push,
                    BufferOp::Pop | BufferOp::PopPush => BufferOp::PopPush,
                };
            }
        }
    }
    Schedule { table, phase: 0 }
}

/// Generate the M-type pooling schedule appended to a conv stage's
/// hand-off under the block-reuse scheme: period `2·S_p` cycles
/// (= `S_p` pixel slots), comparing/scaling each arriving activation and
/// emitting one pooled beat per window (paper Section II-C:
/// "Its period is related to pooling stride (p = 2·S_p)").
pub fn pooling_schedule(s_p: usize, max: bool) -> Schedule {
    let mut table = Vec::with_capacity(s_p);
    for i in 0..s_p {
        let last = i == s_p - 1;
        table.push(Instr::M {
            rx: RxCtrl::NONE.with(RxSource::West),
            func: if max { Func::Cmp } else { Func::Mul },
            tx: if last { TxCtrl::NextLayer } else { TxCtrl::None },
            opc: if last {
                MOpcode::ApplyOut
            } else {
                MOpcode::Apply
            },
        });
    }
    Schedule { table, phase: 0 }
}

/// Generate the schedule for one FC tile (paper Fig. 2): each tile
/// multiplies its input slice once per inference and forwards the
/// partial sum down the column; the period is one beat per input slice.
///
/// `rblock` = position down the column; the bottom tile applies the
/// activation (M-type) and emits the output slice.
pub fn fc_tile_schedule(rblock: usize, rblocks: usize, relu: bool) -> Schedule {
    let is_bottom = rblock == rblocks - 1;
    let rx = if rblock == 0 {
        RxCtrl::NONE.with(RxSource::Pe)
    } else {
        RxCtrl::NONE.with(RxSource::North).with(RxSource::Pe)
    };
    let instr = if is_bottom && rblocks > 0 {
        Instr::M {
            rx,
            func: if relu { Func::Act } else { Func::Quant },
            tx: TxCtrl::NextLayer,
            opc: MOpcode::ApplyOut,
        }
    } else {
        Instr::C {
            rx,
            sum: true,
            buffer: BufferOp::None,
            tx: TxCtrl::Chain,
            opc: COpcode::AccOut,
        }
    };
    Schedule {
        table: vec![instr],
        phase: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::for_all;

    fn role(kr: usize, kc: usize, k: usize) -> ConvRole {
        ConvRole {
            kr,
            kc,
            cb: 0,
            is_chain_start: kr == 0 && kc == 0,
            is_row_end: kc == k - 1,
            is_last: kr == k - 1 && kc == k - 1,
            is_row_head: kc == 0 && kr > 0,
        }
    }

    #[test]
    fn period_matches_paper_formula_stride1() {
        // p = 2(P + W) for Sc = 1 — Section II-C.
        let g = ConvGeometry::new(3, 1, 1, 32, 32);
        assert_eq!(g.paper_period_cycles(), 2 * (1 + 32));
        assert_eq!(g.period_cycles(), 2 * (32 + 2));
        let s = conv_tile_schedule(&g, &role(0, 0, 3), true);
        assert_eq!(
            s.period() * CYCLES_PER_SLOT,
            CYCLES_PER_SLOT * g.wp(),
            "table covers one padded row for stride 1"
        );
    }

    #[test]
    fn schedules_compress_into_hardware_table() {
        // Even a 224-wide VGG row must fit after RLE.
        let g = ConvGeometry::new(3, 1, 1, 224, 224);
        for kr in 0..3 {
            for kc in 0..3 {
                let s = conv_tile_schedule(&g, &role(kr, kc, 3), true);
                assert!(
                    s.compressed_len() <= crate::consts::SCHEDULE_TABLE_ENTRIES,
                    "kr={kr} kc={kc}: {} runs",
                    s.compressed_len()
                );
                assert!(s.compressed_len() <= 8, "steady state is a few runs");
            }
        }
    }

    #[test]
    fn stride2_table_covers_two_rows_and_shields() {
        let g = ConvGeometry::new(3, 2, 1, 8, 8);
        let s = conv_tile_schedule(&g, &role(0, 1, 3), true);
        assert_eq!(s.period(), 2 * g.wp());
        // second row (rowmod 1) must be fully shielded: no sums
        for u in 0..g.wp() {
            match s.table[g.wp() + u] {
                Instr::C { sum, tx, .. } => {
                    assert!(!sum && tx == TxCtrl::None, "u={u} not shielded");
                }
                _ => panic!("C-type expected"),
            }
        }
        // first row: valid only at u = kc + 2*ox
        for u in 0..g.wp() {
            let valid = u >= 1 && (u - 1) % 2 == 0 && (u - 1) / 2 < g.out_w;
            match s.table[u] {
                Instr::C { sum, .. } => assert_eq!(sum, valid, "u={u}"),
                _ => panic!("C-type expected"),
            }
        }
    }

    #[test]
    fn last_tile_emits_mtype_with_act() {
        let g = ConvGeometry::new(3, 1, 1, 8, 8);
        let s = conv_tile_schedule(&g, &role(2, 2, 3), true);
        let m_out = s
            .table
            .iter()
            .filter(|i| {
                matches!(
                    i,
                    Instr::M {
                        func: Func::Act,
                        opc: MOpcode::ApplyOut,
                        tx: TxCtrl::NextLayer,
                        ..
                    }
                )
            })
            .count();
        assert_eq!(m_out, g.out_w, "one activation per output column");
    }

    #[test]
    fn linear_conv_uses_quant_not_act() {
        let g = ConvGeometry::new(1, 1, 0, 4, 4);
        let r = ConvRole {
            kr: 0,
            kc: 0,
            cb: 0,
            is_chain_start: true,
            is_row_end: true,
            is_last: true,
            is_row_head: false,
        };
        let s = conv_tile_schedule(&g, &r, false);
        assert!(s
            .table
            .iter()
            .any(|i| matches!(i, Instr::M { func: Func::Quant, .. })));
        assert!(!s
            .table
            .iter()
            .any(|i| matches!(i, Instr::M { func: Func::Act, .. })));
    }

    #[test]
    fn row_head_pushes_and_pops() {
        let g = ConvGeometry::new(3, 1, 1, 8, 8);
        let s = conv_tile_schedule(&g, &role(1, 0, 3), true);
        let mut pushes = 0;
        let mut pops = 0;
        for i in &s.table {
            if let Instr::C { buffer, .. } = i {
                match buffer {
                    BufferOp::Push => pushes += 1,
                    BufferOp::Pop => pops += 1,
                    BufferOp::PopPush => {
                        pushes += 1;
                        pops += 1;
                    }
                    BufferOp::None => {}
                }
            }
        }
        // one push and one pop per output column per row period
        assert_eq!(pushes, g.out_w);
        assert_eq!(pops, g.out_w);
    }

    #[test]
    fn chain_start_receives_only_pe() {
        let g = ConvGeometry::new(3, 1, 1, 8, 8);
        let s = conv_tile_schedule(&g, &role(0, 0, 3), true);
        for i in &s.table {
            if let Instr::C { rx, .. } = i {
                assert!(rx.contains(RxSource::Pe));
                assert!(!rx.contains(RxSource::West));
            }
        }
    }

    #[test]
    fn pooling_period_matches_paper() {
        // p = 2·S_p cycles = S_p slots.
        let s = pooling_schedule(2, true);
        assert_eq!(s.period() * CYCLES_PER_SLOT, 4);
        assert!(matches!(
            s.table[1],
            Instr::M {
                func: Func::Cmp,
                opc: MOpcode::ApplyOut,
                ..
            }
        ));
    }

    #[test]
    fn fc_bottom_tile_activates() {
        let top = fc_tile_schedule(0, 3, true);
        let mid = fc_tile_schedule(1, 3, true);
        let bot = fc_tile_schedule(2, 3, true);
        assert!(matches!(top.table[0], Instr::C { .. }));
        assert!(matches!(mid.table[0], Instr::C { sum: true, .. }));
        assert!(matches!(
            bot.table[0],
            Instr::M {
                func: Func::Act,
                tx: TxCtrl::NextLayer,
                ..
            }
        ));
        // mid receives from the column (North) and its PE
        if let Instr::C { rx, .. } = mid.table[0] {
            assert!(rx.contains(RxSource::North) && rx.contains(RxSource::Pe));
        }
    }

    #[test]
    fn prop_schedule_period_invariants() {
        for_all("schedule_period", 30, |rng| {
            let k = rng.range(1, 5);
            let stride = rng.range(1, 2);
            let pad = rng.below(k.min(2) + 1);
            let n = rng.range(k.max(2), 16);
            let g = ConvGeometry::new(k, stride, pad, n, n);
            let kr = rng.below(k);
            let kc = rng.below(k);
            let s = conv_tile_schedule(&g, &role(kr, kc, k), true);
            assert_eq!(s.period(), g.wp() * stride);
            // sums only on valid slots
            let sums = s
                .table
                .iter()
                .filter(|i| matches!(i, Instr::C { sum: true, .. })
                    || matches!(i, Instr::M { opc: MOpcode::ApplyOut, .. }))
                .count();
            assert_eq!(sums, g.out_w, "one contribution per output column");
        });
    }
}
