//! The mapping plane's explicit plan IR and its build phases.
//!
//! [`super::mapper::Compiler::compile`] used to be a monolith that
//! fused allocation, placement, scheduling and chip partitioning in
//! one pass. The pipeline is now four explicit phases around the
//! [`MappingPlan`] IR:
//!
//! 1. **allocate** — turn every weight layer into its logical tile
//!    array (`K² x ⌈C/N_c⌉` chains per output-channel block for conv,
//!    `⌈C_in/N_c⌉`-tile columns for FC, a 1x1 conv array per projected
//!    skip) and plan the per-layer duplication factors (pooling-scheme
//!    replication and the `sync_chips` water-fill) — [`allocate`] and
//!    [`plan_duplication`];
//! 2. **place** — walk the allocations in layer order and pin every
//!    chain to mesh coordinates through a pluggable [`Placement`]
//!    strategy (serpentine baseline or column-major; both keep every
//!    partial-sum hop mesh-local), honoring
//!    [`ArchConfig::chip_aligned_chains`] — [`place`];
//! 3. **schedule** — generate each placed tile's periodic ROFM program
//!    and RIFM config (this stays in
//!    [`super::mapper::Compiler::materialize`], which consumes the
//!    plan);
//! 4. **partition** — cut the placed tile span into
//!    `tiles_per_chip`-sized chips — [`partition`].
//!
//! The IR is deliberately weight-free: a `MappingPlan` is a pure
//! function of `(Network, ArchConfig)`, cheap enough for the mapping
//! explorer (`super::explore`) to build dozens of them per model.
//!
//! ## Fault-aware placement
//!
//! The fault plane (`sim::fault`, `serve`'s canary checks) names bad
//! physical resources by [`Coord`]; [`TileMask`] carries that set into
//! the **place** phase. [`build_masked`] / [`place_masked`] produce a
//! plan that provably uses none of the masked tiles or links: a chain
//! whose candidate span touches a masked resource is slid forward in
//! flat-cursor space until it clears (whole-chain shifts only, so
//! chains stay contiguous and every psum hop stays mesh-local — the
//! COM locality invariant survives masking under both [`Placement`]
//! strategies). The cost is the skipped tiles: a masked plan may span
//! more chips, which the explorer and the recovery path surface as a
//! measurable latency/energy penalty. An empty mask reproduces the
//! unmasked plan bit-for-bit.

use std::collections::BTreeSet;
use std::fmt;

use anyhow::Result;

use crate::coordinator::mapper::ArchConfig;
use crate::coordinator::schedule::ConvGeometry;
use crate::model::{LayerKind, Network, TensorShape};
use crate::noc::{column_major, serpentine, Coord};

/// Pluggable placement strategy for the **place** phase: how a chain of
/// `n` logically-consecutive tiles is pinned to mesh coordinates. Every
/// strategy must keep consecutive chain positions mesh-adjacent (the
/// COM locality invariant, checked by `noc::chain_is_local`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Row-serpentine (boustrophedon) — the paper's baseline layout.
    Serpentine,
    /// Column-serpentine: chains run down columns, transposing the
    /// link-traffic landscape (`noc::column_major`).
    ColumnMajor,
}

impl Placement {
    /// Coordinates for a chain of `n` tiles starting at flat index
    /// `start`.
    pub fn coords(
        self,
        start: usize,
        n: usize,
        mesh_cols: usize,
        tiles_per_chip: usize,
    ) -> Vec<Coord> {
        match self {
            Placement::Serpentine => serpentine(start, n, mesh_cols, tiles_per_chip),
            Placement::ColumnMajor => column_major(start, n, mesh_cols, tiles_per_chip),
        }
    }

    /// Canonical config/wire name.
    pub fn name(self) -> &'static str {
        match self {
            Placement::Serpentine => "serpentine",
            Placement::ColumnMajor => "column-major",
        }
    }

    /// Parse a config/wire name (case-insensitive, `_`/`-`
    /// interchangeable).
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().replace('_', "-").as_str() {
            "serpentine" => Ok(Placement::Serpentine),
            "column-major" => Ok(Placement::ColumnMajor),
            other => anyhow::bail!(
                "unknown placement {other:?} (use \"serpentine\" or \"column-major\")"
            ),
        }
    }

    /// Every strategy, for sweeps.
    pub const ALL: [Placement; 2] = [Placement::Serpentine, Placement::ColumnMajor];
}

/// Physical resources the **place** phase must route around: tiles
/// known (or suspected) bad, and directed-agnostic links between
/// mesh-adjacent tiles. Built from a detected `sim::fault::FaultPlan`
/// (`TileMask::from_coords`) or by hand; consumed by [`build_masked`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TileMask {
    tiles: BTreeSet<Coord>,
    /// Banned links, stored as normalized (min, max) endpoint pairs so
    /// `a→b` and `b→a` are the same physical link.
    links: BTreeSet<(Coord, Coord)>,
}

impl TileMask {
    pub fn new() -> Self {
        Self::default()
    }

    /// A mask banning every coordinate in `coords` (the usual recovery
    /// path: `FaultPlan::coords()` → mask → re-place).
    pub fn from_coords<I: IntoIterator<Item = Coord>>(coords: I) -> Self {
        Self {
            tiles: coords.into_iter().collect(),
            links: BTreeSet::new(),
        }
    }

    /// Ban a tile outright.
    pub fn ban_tile(&mut self, c: Coord) -> &mut Self {
        self.tiles.insert(c);
        self
    }

    /// Ban the link between two (mesh-adjacent) tiles; order of the
    /// endpoints does not matter.
    pub fn ban_link(&mut self, a: Coord, b: Coord) -> &mut Self {
        self.links.insert(if a <= b { (a, b) } else { (b, a) });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.tiles.is_empty() && self.links.is_empty()
    }

    /// Banned tiles + banned links.
    pub fn len(&self) -> usize {
        self.tiles.len() + self.links.len()
    }

    /// Is this tile banned?
    pub fn bans_tile(&self, c: Coord) -> bool {
        self.tiles.contains(&c)
    }

    /// Is the link between these tiles banned?
    pub fn bans_link(&self, a: Coord, b: Coord) -> bool {
        let key = if a <= b { (a, b) } else { (b, a) };
        self.links.contains(&key)
    }

    /// The banned tile coordinates, ascending.
    pub fn tiles(&self) -> impl Iterator<Item = &Coord> {
        self.tiles.iter()
    }

    /// Would a chain over these coordinates use any banned resource —
    /// a banned tile, or a banned link between consecutive hops?
    pub fn allows_chain(&self, coords: &[Coord]) -> bool {
        if coords.iter().any(|c| self.tiles.contains(c)) {
            return false;
        }
        coords
            .windows(2)
            .all(|w| !self.bans_link(w[0], w[1]))
    }

    /// Highest chip any banned resource touches (None for an empty
    /// mask). Every flat index past this chip is guaranteed clean,
    /// which bounds the masked-placement retry loop.
    pub fn max_chip(&self) -> Option<usize> {
        let t = self.tiles.iter().map(|c| c.chip).max();
        let l = self
            .links
            .iter()
            .map(|(a, b)| a.chip.max(b.chip))
            .max();
        t.max(l)
    }
}

impl fmt::Display for TileMask {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut parts: Vec<String> = self
            .tiles
            .iter()
            .map(|c| format!("{}:{}:{}", c.chip, c.row, c.col))
            .collect();
        parts.extend(self.links.iter().map(|(a, b)| {
            format!(
                "{}:{}:{}-{}:{}:{}",
                a.chip, a.row, a.col, b.chip, b.row, b.col
            )
        }));
        write!(f, "{}", parts.join(","))
    }
}

/// Output of the **allocate** phase for one network layer: the logical
/// tile array, before any coordinate is assigned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerAlloc {
    /// Conv layer or 1x1 projection: `chains` chains (one per
    /// output-channel block) of `chain_len * dup` tiles each.
    Conv {
        chains: usize,
        chain_len: usize,
        dup: usize,
    },
    /// FC layer: `columns` columns (one per output-feature block) of
    /// `column_len` tiles each.
    Fc { columns: usize, column_len: usize },
    /// No tiles: pooling (fused or in-network), identity residual add,
    /// flatten.
    None,
}

/// One placed chain: the flat cursor position it starts at (after any
/// chip alignment) and the mesh coordinate of every tile.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChainPlan {
    pub start: usize,
    pub coords: Vec<Coord>,
}

/// Placed plan for a conv (or projection) layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvPlan {
    /// Tiles per replica chain (`K² x ⌈C/N_c⌉`).
    pub chain_len: usize,
    /// Weight-duplication replicas per chain.
    pub dup: usize,
    /// One placed chain per output-channel block; each covers
    /// `chain_len * dup` tiles.
    pub chains: Vec<ChainPlan>,
}

/// Placed plan for an FC layer: one placed column per output-feature
/// block.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FcPlan {
    pub columns: Vec<ChainPlan>,
}

/// Placed plan for one network layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayerPlan {
    Conv(ConvPlan),
    Fc(FcPlan),
    None,
}

/// The mapping-plane IR: every weight layer's tile allocation pinned to
/// mesh coordinates, plus the chip partition. Built by [`build`]
/// (allocate → place → partition); consumed by
/// [`super::mapper::Compiler::materialize`] (schedule) and inspected by
/// the explorer and observability planes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MappingPlan {
    pub arch: ArchConfig,
    /// Indexed by network layer (fused pool layers are `None`; their
    /// tiles belong to the preceding conv's plan).
    pub layers: Vec<LayerPlan>,
    /// Total tiles allocated, including chip-alignment padding.
    pub total_tiles: usize,
    /// Chips required at `arch.tiles_per_chip`.
    pub chips: usize,
}

impl MappingPlan {
    /// Tiles allocated to one layer (replicas included; alignment
    /// padding is not attributed to any layer).
    pub fn layer_tiles(&self, layer: usize) -> usize {
        match &self.layers[layer] {
            LayerPlan::Conv(c) => c.chains.iter().map(|ch| ch.coords.len()).sum(),
            LayerPlan::Fc(f) => f.columns.iter().map(|col| col.coords.len()).sum(),
            LayerPlan::None => 0,
        }
    }
}

/// Build the full plan: allocate → place → partition.
pub fn build(net: &Network, arch: &ArchConfig) -> Result<MappingPlan> {
    let shapes = net.shapes()?;
    let dups = plan_duplication(net, &shapes, arch)?;
    let allocs = allocate(net, &shapes, arch, &dups)?;
    Ok(place(&allocs, arch))
}

/// [`build`], routing placement around a [`TileMask`] of known-bad
/// resources. The result provably uses none of the masked tiles/links
/// (every chain's span is checked before it is pinned); an empty mask
/// reproduces [`build`] bit-for-bit.
pub fn build_masked(net: &Network, arch: &ArchConfig, mask: &TileMask) -> Result<MappingPlan> {
    let shapes = net.shapes()?;
    let dups = plan_duplication(net, &shapes, arch)?;
    let allocs = allocate(net, &shapes, arch, &dups)?;
    place_masked(&allocs, arch, mask)
}

/// Phase 1 (**allocate**, tile arrays): the logical tile array of every
/// layer, mirroring the Section III formulas — `K² · ⌈C/N_c⌉` tiles per
/// chain and `⌈M/N_m⌉` chains for conv, a `⌈C_in/N_c⌉ x ⌈C_out/N_m⌉`
/// grid for FC, a 1x1 conv array per projected skip. Walks layers in
/// network order with the same fused-pool skipping the materializer
/// uses, so the two phases can never disagree on which layer owns which
/// allocation.
pub fn allocate(
    net: &Network,
    shapes: &[TensorShape],
    arch: &ArchConfig,
    dups: &[usize],
) -> Result<Vec<LayerAlloc>> {
    let mut allocs = vec![LayerAlloc::None; net.layers.len()];
    let mut in_shape = net.input;
    let mut i = 0usize;
    while i < net.layers.len() {
        let out_shape = shapes[i];
        match &net.layers[i].kind {
            LayerKind::Conv2d {
                out_ch, kernel, ..
            } => {
                let cb = in_shape.c.div_ceil(arch.n_c);
                let mb = out_ch.div_ceil(arch.n_m);
                allocs[i] = LayerAlloc::Conv {
                    chains: mb,
                    chain_len: kernel * kernel * cb,
                    dup: dups[i],
                };
                // a directly following pool layer is fused into this
                // conv's hand-off and owns no tiles of its own
                if matches!(
                    net.layers.get(i + 1).map(|l| &l.kind),
                    Some(LayerKind::MaxPool2d { .. }) | Some(LayerKind::AvgPool2d { .. })
                ) {
                    in_shape = shapes[i + 1];
                    i += 2;
                    continue;
                }
            }
            LayerKind::Fc { out_features, .. } => {
                allocs[i] = LayerAlloc::Fc {
                    columns: out_features.div_ceil(arch.n_m),
                    column_len: in_shape.c.div_ceil(arch.n_c),
                };
            }
            LayerKind::ResAdd {
                from,
                proj: Some(p),
            } => {
                let src = shapes[*from];
                allocs[i] = LayerAlloc::Conv {
                    chains: p.out_ch.div_ceil(arch.n_m),
                    chain_len: src.c.div_ceil(arch.n_c),
                    dup: dups[i],
                };
            }
            _ => {}
        }
        in_shape = out_shape;
        i += 1;
    }
    Ok(allocs)
}

/// Phase 1 (**allocate**, stream rates): per-layer weight-duplication
/// factors.
///
/// Without a `sync_chips` budget this returns the pooling-scheme
/// factors only (1 under block reuse, `K_p²` for pre-pool convs under
/// weight duplication, Fig. 4(b)). With a budget it *water-fills*:
/// repeatedly duplicate the stage with the longest steady-state period
/// (`⌈pixels/dup⌉`) until the chip budget is exhausted — this is how
/// the paper's Table IV tile counts (240 x 5 for VGG-11 vs the
/// 168-tile Section III-B minimum) and "layer synchronization"
/// throughput arise. Each replica streams `1/dup` of the IFM, so
/// per-image event counts are unchanged (window-halo traffic between
/// replicas is below model resolution); only the stage period shrinks.
pub fn plan_duplication(
    net: &Network,
    shapes: &[TensorShape],
    arch: &ArchConfig,
) -> Result<Vec<usize>> {
    use super::mapper::PoolingScheme;
    struct Entry {
        layer: usize,
        tiles: usize,
        pixels: usize,
        dup: usize,
    }
    let mut dups = vec![1usize; net.layers.len()];
    let mut entries: Vec<Entry> = Vec::new();
    let mut fixed = 0usize; // non-duplicable tiles (FC grids)
    let mut in_shape = net.input;
    let mut i = 0usize;
    while i < net.layers.len() {
        let layer = &net.layers[i];
        let out_shape = shapes[i];
        match &layer.kind {
            LayerKind::Conv2d {
                out_ch,
                kernel,
                stride,
                padding,
                ..
            } => {
                let pool_k = match net.layers.get(i + 1).map(|l| &l.kind) {
                    Some(LayerKind::MaxPool2d { kernel, .. })
                    | Some(LayerKind::AvgPool2d { kernel, .. }) => Some(*kernel),
                    _ => None,
                };
                let g = ConvGeometry::new(*kernel, *stride, *padding, in_shape.h, in_shape.w);
                let cb = in_shape.c.div_ceil(arch.n_c);
                let mb = out_ch.div_ceil(arch.n_m);
                let chain = kernel * kernel * cb;
                let dup0 = match (pool_k, arch.pooling) {
                    (Some(kp), PoolingScheme::WeightDuplication) => kp * kp,
                    _ => 1,
                };
                entries.push(Entry {
                    layer: i,
                    tiles: chain * mb,
                    pixels: g.stream_slots(),
                    dup: dup0,
                });
                if pool_k.is_some() {
                    in_shape = shapes[i + 1];
                    i += 2;
                    continue;
                }
            }
            LayerKind::Fc { out_features, .. } => {
                fixed +=
                    in_shape.c.div_ceil(arch.n_c) * out_features.div_ceil(arch.n_m);
            }
            LayerKind::ResAdd { proj: Some(p), from } => {
                let src = shapes[*from];
                let g = ConvGeometry::new(1, p.stride, 0, src.h, src.w);
                let cb = src.c.div_ceil(arch.n_c);
                let mb = p.out_ch.div_ceil(arch.n_m);
                entries.push(Entry {
                    layer: i,
                    tiles: cb * mb,
                    pixels: g.stream_slots(),
                    dup: 1,
                });
            }
            _ => {}
        }
        in_shape = out_shape;
        i += 1;
    }

    if let Some(chips) = arch.sync_chips {
        let budget = chips * arch.tiles_per_chip;
        let mut used = fixed + entries.iter().map(|e| e.tiles * e.dup).sum::<usize>();
        loop {
            // current bottleneck stage
            let Some(bi) = (0..entries.len()).max_by_key(|&j| {
                let e = &entries[j];
                e.pixels.div_ceil(e.dup)
            }) else {
                break;
            };
            let e = &entries[bi];
            // one replica cannot stream less than one pixel, and an
            // unaffordable bottleneck means no further period gain
            if e.dup >= e.pixels || used + e.tiles > budget {
                break;
            }
            entries[bi].dup += 1;
            used += entries[bi].tiles;
        }
    }
    for e in &entries {
        dups[e.layer] = e.dup;
    }
    Ok(dups)
}

/// Phase 2 (**place**) + phase 4 (**partition**): walk the allocations
/// in layer order, advancing one flat tile cursor, aligning chains to
/// chip boundaries when configured, and pinning every chain through the
/// arch's [`Placement`] strategy; then cut the span into chips.
pub fn place(allocs: &[LayerAlloc], arch: &ArchConfig) -> MappingPlan {
    let mut layers = Vec::with_capacity(allocs.len());
    let mut cursor = 0usize;
    for alloc in allocs {
        layers.push(match alloc {
            LayerAlloc::None => LayerPlan::None,
            LayerAlloc::Conv {
                chains,
                chain_len,
                dup,
            } => {
                let mut placed = Vec::with_capacity(*chains);
                for _ in 0..*chains {
                    placed.push(place_chain(&mut cursor, chain_len * dup, arch));
                }
                LayerPlan::Conv(ConvPlan {
                    chain_len: *chain_len,
                    dup: *dup,
                    chains: placed,
                })
            }
            LayerAlloc::Fc {
                columns,
                column_len,
            } => {
                let mut placed = Vec::with_capacity(*columns);
                for _ in 0..*columns {
                    placed.push(place_chain(&mut cursor, *column_len, arch));
                }
                LayerPlan::Fc(FcPlan { columns: placed })
            }
        });
    }
    let total_tiles = cursor;
    MappingPlan {
        arch: *arch,
        layers,
        total_tiles,
        chips: partition(total_tiles, arch),
    }
}

fn place_chain(cursor: &mut usize, n: usize, arch: &ArchConfig) -> ChainPlan {
    align_chain(cursor, n, arch);
    let start = *cursor;
    let coords = arch
        .placement
        .coords(start, n, arch.mesh_cols, arch.tiles_per_chip);
    *cursor += n;
    ChainPlan { start, coords }
}

/// [`place`] with a [`TileMask`]: identical cursor walk, except a chain
/// whose candidate span touches a masked tile or link is slid forward
/// (whole-chain shifts, so contiguity — and with it mesh-locality — is
/// preserved) until it clears. Fails only if the mask is degenerate
/// (the retry bound is defensive: both placement strategies satisfy
/// `chip = flat_index / tiles_per_chip`, so every flat index past the
/// mask's highest chip is clean and the loop must terminate there).
pub fn place_masked(
    allocs: &[LayerAlloc],
    arch: &ArchConfig,
    mask: &TileMask,
) -> Result<MappingPlan> {
    let mut layers = Vec::with_capacity(allocs.len());
    let mut cursor = 0usize;
    for alloc in allocs {
        layers.push(match alloc {
            LayerAlloc::None => LayerPlan::None,
            LayerAlloc::Conv {
                chains,
                chain_len,
                dup,
            } => {
                let mut placed = Vec::with_capacity(*chains);
                for _ in 0..*chains {
                    placed.push(place_chain_masked(&mut cursor, chain_len * dup, arch, mask)?);
                }
                LayerPlan::Conv(ConvPlan {
                    chain_len: *chain_len,
                    dup: *dup,
                    chains: placed,
                })
            }
            LayerAlloc::Fc {
                columns,
                column_len,
            } => {
                let mut placed = Vec::with_capacity(*columns);
                for _ in 0..*columns {
                    placed.push(place_chain_masked(&mut cursor, *column_len, arch, mask)?);
                }
                LayerPlan::Fc(FcPlan { columns: placed })
            }
        });
    }
    let total_tiles = cursor;
    Ok(MappingPlan {
        arch: *arch,
        layers,
        total_tiles,
        chips: partition(total_tiles, arch),
    })
}

fn place_chain_masked(
    cursor: &mut usize,
    n: usize,
    arch: &ArchConfig,
    mask: &TileMask,
) -> Result<ChainPlan> {
    // Past the mask's highest chip every candidate is clean; give the
    // loop one spare chip of headroom and treat exceeding it as a bug.
    let limit = (mask.max_chip().unwrap_or(0) + 2) * arch.tiles_per_chip + n;
    loop {
        align_chain(cursor, n, arch);
        let start = *cursor;
        let coords = arch
            .placement
            .coords(start, n, arch.mesh_cols, arch.tiles_per_chip);
        if mask.allows_chain(&coords) {
            *cursor += n;
            return Ok(ChainPlan { start, coords });
        }
        if start > limit {
            anyhow::bail!(
                "masked placement did not converge: a {n}-tile chain found no clean span \
                 by flat index {start} (mask: {mask})"
            );
        }
        // slide the whole chain one tile forward and retry — shifting
        // the start (never skipping mid-chain tiles) keeps the span
        // contiguous in flat space, hence mesh-local
        *cursor = start + 1;
    }
}

/// Under `chip_aligned_chains`, advance the cursor to the next chip
/// boundary when an `n`-tile chain would otherwise straddle one (chains
/// longer than a chip must straddle regardless). Costs a few pad tiles;
/// saves inter-chip energy (ablation `benches/ablation_chip_align.rs`).
fn align_chain(cursor: &mut usize, n: usize, arch: &ArchConfig) {
    if !arch.chip_aligned_chains || n > arch.tiles_per_chip {
        return;
    }
    let per = arch.tiles_per_chip;
    let used = *cursor % per;
    if used + n > per {
        *cursor += per - used; // pad tiles: unused crossbars
    }
}

/// Phase 4 (**partition**): chips required for a placed tile span.
pub fn partition(total_tiles: usize, arch: &ArchConfig) -> usize {
    total_tiles.div_ceil(arch.tiles_per_chip).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::noc::chain_is_local;

    #[test]
    fn placement_names_roundtrip() {
        for p in Placement::ALL {
            assert_eq!(Placement::parse(p.name()).unwrap(), p);
        }
        assert_eq!(
            Placement::parse("COLUMN_MAJOR").unwrap(),
            Placement::ColumnMajor
        );
        assert!(Placement::parse("diagonal").is_err());
    }

    #[test]
    fn plan_matches_section3_formulas() {
        // tiny-cnn at the default arch: every chain's span is
        // chain_len * dup, placed contiguously and mesh-locally
        let net = zoo::tiny_cnn();
        let arch = ArchConfig::default();
        let plan = build(&net, &arch).unwrap();
        assert_eq!(plan.layers.len(), net.layers.len());
        assert!(plan.total_tiles > 0);
        assert_eq!(plan.chips, plan.total_tiles.div_ceil(arch.tiles_per_chip));
        let mut seen = 0usize;
        for (li, lp) in plan.layers.iter().enumerate() {
            match lp {
                LayerPlan::Conv(c) => {
                    for ch in &c.chains {
                        assert_eq!(ch.coords.len(), c.chain_len * c.dup, "layer {li}");
                        assert!(chain_is_local(&ch.coords), "layer {li}");
                        assert!(ch.start >= seen);
                        seen = ch.start + ch.coords.len();
                    }
                }
                LayerPlan::Fc(f) => {
                    for col in &f.columns {
                        assert!(chain_is_local(&col.coords), "layer {li}");
                        assert!(col.start >= seen);
                        seen = col.start + col.coords.len();
                    }
                }
                LayerPlan::None => {}
            }
        }
        assert_eq!(seen, plan.total_tiles, "cursor accounts for every tile");
    }

    #[test]
    fn column_major_plan_is_mesh_local_too() {
        let net = zoo::resnet18_cifar();
        let mut arch = ArchConfig::default();
        arch.placement = Placement::ColumnMajor;
        let plan = build(&net, &arch).unwrap();
        for lp in &plan.layers {
            if let LayerPlan::Conv(c) = lp {
                for ch in &c.chains {
                    assert!(chain_is_local(&ch.coords));
                }
            }
        }
        // placement changes coordinates, never the tile budget
        let base = build(&net, &ArchConfig::default()).unwrap();
        assert_eq!(plan.total_tiles, base.total_tiles);
        assert_eq!(plan.chips, base.chips);
    }

    /// Every coordinate a plan pins, in placement order.
    fn all_coords(plan: &MappingPlan) -> Vec<Coord> {
        let mut out = Vec::new();
        for lp in &plan.layers {
            match lp {
                LayerPlan::Conv(c) => {
                    for ch in &c.chains {
                        out.extend(ch.coords.iter().copied());
                    }
                }
                LayerPlan::Fc(f) => {
                    for col in &f.columns {
                        out.extend(col.coords.iter().copied());
                    }
                }
                LayerPlan::None => {}
            }
        }
        out
    }

    #[test]
    fn empty_mask_reproduces_unmasked_plan() {
        let net = zoo::tiny_cnn();
        for placement in Placement::ALL {
            let mut arch = ArchConfig::default();
            arch.placement = placement;
            let base = build(&net, &arch).unwrap();
            let masked = build_masked(&net, &arch, &TileMask::new()).unwrap();
            assert_eq!(base, masked, "{placement:?}: empty mask must be a no-op");
        }
    }

    #[test]
    fn masked_plan_avoids_banned_tiles_and_stays_local() {
        let net = zoo::tiny_cnn();
        for placement in Placement::ALL {
            let mut arch = ArchConfig::default();
            arch.placement = placement;
            let base = build(&net, &arch).unwrap();
            // ban the very first placed tile and one mid-plan tile
            let coords = all_coords(&base);
            let mut mask = TileMask::new();
            mask.ban_tile(coords[0]);
            mask.ban_tile(coords[coords.len() / 2]);
            let masked = build_masked(&net, &arch, &mask).unwrap();
            for c in all_coords(&masked) {
                assert!(!mask.bans_tile(c), "{placement:?}: banned tile {c:?} used");
            }
            for lp in &masked.layers {
                if let LayerPlan::Conv(c) = lp {
                    for ch in &c.chains {
                        assert!(chain_is_local(&ch.coords), "{placement:?}");
                    }
                }
            }
            // routing around costs tiles, never saves them
            assert!(masked.total_tiles >= base.total_tiles);
        }
    }

    #[test]
    fn masked_plan_avoids_banned_links() {
        let net = zoo::tiny_cnn();
        let arch = ArchConfig::default();
        let base = build(&net, &arch).unwrap();
        // ban the first chain's first hop
        let coords = all_coords(&base);
        let mut mask = TileMask::new();
        mask.ban_link(coords[0], coords[1]);
        assert!(mask.bans_link(coords[1], coords[0]), "links are undirected");
        let masked = build_masked(&net, &arch, &mask).unwrap();
        for lp in &masked.layers {
            let chains: &[ChainPlan] = match lp {
                LayerPlan::Conv(c) => &c.chains,
                LayerPlan::Fc(f) => &f.columns,
                LayerPlan::None => continue,
            };
            for ch in chains {
                for w in ch.coords.windows(2) {
                    assert!(!mask.bans_link(w[0], w[1]), "banned link used");
                }
            }
        }
    }

    #[test]
    fn mask_display_and_max_chip() {
        let mut mask = TileMask::new();
        assert!(mask.is_empty());
        assert_eq!(mask.max_chip(), None);
        mask.ban_tile(Coord::new(1, 0, 2));
        mask.ban_link(Coord::new(0, 0, 0), Coord::new(0, 0, 1));
        assert_eq!(mask.len(), 2);
        assert_eq!(mask.max_chip(), Some(1));
        assert_eq!(mask.to_string(), "1:0:2,0:0:0-0:0:1");
    }

    #[test]
    fn layer_tiles_sums_replicas() {
        let net = zoo::vgg11_cifar();
        let arch = ArchConfig::table4(5);
        let plan = build(&net, &arch).unwrap();
        let sum: usize = (0..plan.layers.len()).map(|i| plan.layer_tiles(i)).sum();
        // no alignment configured: every allocated tile belongs to a layer
        assert_eq!(sum, plan.total_tiles);
    }
}
