//! The compiled artifact: everything a Domino array needs to run a
//! network — per-tile weights, RIFM configuration, ROFM schedules and
//! mesh placement — grouped into pipeline stages.
//!
//! A [`Program`] is produced once by the [`super::mapper::Compiler`]
//! ("The compiler generates instructions and configuration for each tile
//! based on initial input data and the DNN structure", Section II-C) and
//! is immutable afterwards: at run time there is no global controller,
//! only tiles executing their local periodic schedules.

use crate::coordinator::isa::Schedule;
use crate::coordinator::mapper::ArchConfig;
use crate::model::{Network, TensorShape};
use crate::noc::Coord;
use crate::tile::rifm::RifmConfig;

/// Pooling fused behind a conv layer's last tile (paper Section III-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolSpec {
    pub max: bool,
    pub kernel: usize,
    pub stride: usize,
}

/// One tile of a convolution chain.
#[derive(Clone, Debug)]
pub struct ConvTile {
    /// Kernel position (row, col) this tile's weights come from.
    pub kr: usize,
    pub kc: usize,
    /// Input-channel block index.
    pub cb: usize,
    /// Mesh placement.
    pub coord: Coord,
    /// Actual crossbar block dims (rows = channels of block `cb`,
    /// cols = output channels of the chain's mblock).
    pub rows: usize,
    pub cols: usize,
    /// Stationary weights, `[rows][cols]` row-major (c-major, see
    /// `tile::pe`).
    pub weights: Vec<i8>,
    /// The tile's periodic ROFM instruction program.
    pub schedule: Schedule,
    /// RIFM stream configuration.
    pub rifm: RifmConfig,
    /// Chain-topology flags (derived, but precomputed for the engine).
    pub is_chain_start: bool,
    /// Last tile of a kernel row (kc == K-1 and cb == Cb-1): emits
    /// group-sums.
    pub is_row_end: bool,
    /// The stage's final tile (row end of kernel row K-1): applies
    /// M-type activation/pooling and emits OFM beats.
    pub is_last: bool,
    /// First tile of kernel rows > 0 (kc == 0, cb == 0): queues incoming
    /// group-sums in its ROFM buffer.
    pub is_row_head: bool,
}

/// One convolution chain: the `K² x Cb` tiles producing one
/// output-channel block, placed serpentine so every hop is mesh-local.
#[derive(Clone, Debug)]
pub struct ConvChain {
    pub mblock: usize,
    /// Output channels covered by this chain.
    pub m_lo: usize,
    pub m_hi: usize,
    pub tiles: Vec<ConvTile>,
}

/// A compiled convolution stage.
#[derive(Clone, Debug)]
pub struct ConvStage {
    pub in_shape: TensorShape,
    pub out_shape: TensorShape,
    pub k: usize,
    pub stride: usize,
    pub padding: usize,
    pub relu: bool,
    pub shift: u32,
    pub cblocks: usize,
    pub mblocks: usize,
    pub chains: Vec<ConvChain>,
    /// Pooling performed by the last tile / during hand-off
    /// (block-reuse scheme) or via duplicated weights.
    pub fused_pool: Option<PoolSpec>,
    /// With the weight-duplication scheme (Fig. 4(b)) the whole tile
    /// array is replicated `dup` times to emit a full pooling window per
    /// period; `dup = 1` means block reuse.
    pub dup: usize,
}

/// One tile of an FC grid.
#[derive(Clone, Debug)]
pub struct FcTile {
    pub rblock: usize,
    pub coord: Coord,
    pub rows: usize,
    pub cols: usize,
    pub weights: Vec<i8>,
    pub schedule: Schedule,
    pub rifm: RifmConfig,
}

/// One FC column: `⌈C_in/N_c⌉` tiles whose partial sums accumulate down
/// the column (paper Fig. 2), producing one output-feature block.
#[derive(Clone, Debug)]
pub struct FcColumn {
    pub cblock: usize,
    pub c_lo: usize,
    pub c_hi: usize,
    pub tiles: Vec<FcTile>,
}

/// A compiled FC stage.
#[derive(Clone, Debug)]
pub struct FcStage {
    pub in_features: usize,
    pub out_features: usize,
    pub relu: bool,
    pub shift: u32,
    pub rblocks: usize,
    pub cblocks: usize,
    pub columns: Vec<FcColumn>,
}

/// A standalone pooling stage: performed "during data transmission
/// between arrays" (Section III-C) by the previous stage's boundary
/// ROFMs; allocates no new tiles.
#[derive(Clone, Debug)]
pub struct PoolStage {
    pub max: bool,
    pub kernel: usize,
    pub stride: usize,
    pub in_shape: TensorShape,
    pub out_shape: TensorShape,
    /// Incoming stream parallelism inherited from the upstream conv
    /// array's duplication factor (the pool units sit in `dup`
    /// boundary ROFMs and process `dup` pixels per slot).
    pub dup: usize,
}

/// A residual-add stage: the skip stream is routed through RIFM→ROFM
/// shortcuts (Table II `Bp.`) and added at the junction; a projected
/// skip runs through its own 1x1 conv tile array first.
#[derive(Clone, Debug)]
pub struct ResStage {
    /// Index of the *stage* whose output is the skip source.
    pub from_stage: usize,
    /// Optional 1x1 projection conv (compiled like a conv stage).
    pub proj: Option<ConvStage>,
    pub shape: TensorShape,
    /// Add-junction parallelism: the minimum of the incoming stream
    /// rates (main path, skip source, projection).
    pub dup: usize,
}

/// Stage payload.
#[derive(Clone, Debug)]
pub enum StageKind {
    Conv(ConvStage),
    Fc(FcStage),
    Pool(PoolStage),
    Res(ResStage),
    Flatten,
}

/// One pipeline stage (maps 1:1 to a network layer, except pool layers
/// fused into the preceding conv).
#[derive(Clone, Debug)]
pub struct Stage {
    /// Index of the source layer in the network.
    pub layer: usize,
    pub name: String,
    pub kind: StageKind,
}

impl Stage {
    /// Tiles allocated to this stage.
    pub fn tile_count(&self) -> usize {
        match &self.kind {
            StageKind::Conv(c) => c.chains.iter().map(|ch| ch.tiles.len()).sum::<usize>() * c.dup,
            StageKind::Fc(f) => f.columns.iter().map(|c| c.tiles.len()).sum(),
            StageKind::Res(r) => r
                .proj
                .as_ref()
                .map(|p| p.chains.iter().map(|ch| ch.tiles.len()).sum::<usize>() * p.dup)
                .unwrap_or(0),
            StageKind::Pool(_) | StageKind::Flatten => 0,
        }
    }
}

/// A fully compiled network.
#[derive(Clone, Debug)]
pub struct Program {
    pub net: Network,
    pub arch: ArchConfig,
    pub stages: Vec<Stage>,
    /// Total tiles allocated (across chips).
    pub total_tiles: usize,
    /// Chips required at `arch.tiles_per_chip`.
    pub chips: usize,
}

impl Program {
    /// All schedules in the program with their owning stage index
    /// (validation/energy walks).
    pub fn schedules(&self) -> Vec<(usize, &Schedule)> {
        let mut out = Vec::new();
        for (si, stage) in self.stages.iter().enumerate() {
            match &stage.kind {
                StageKind::Conv(c) => {
                    for ch in &c.chains {
                        for t in &ch.tiles {
                            out.push((si, &t.schedule));
                        }
                    }
                }
                StageKind::Fc(f) => {
                    for col in &f.columns {
                        for t in &col.tiles {
                            out.push((si, &t.schedule));
                        }
                    }
                }
                StageKind::Res(r) => {
                    if let Some(p) = &r.proj {
                        for ch in &p.chains {
                            for t in &ch.tiles {
                                out.push((si, &t.schedule));
                            }
                        }
                    }
                }
                _ => {}
            }
        }
        out
    }

    /// Every physical tile coordinate the program occupies (fault
    /// plane: proving a re-mapped program avoids masked resources).
    pub fn tile_coords(&self) -> Vec<Coord> {
        let mut out = Vec::new();
        for stage in &self.stages {
            match &stage.kind {
                StageKind::Conv(c) => {
                    for ch in &c.chains {
                        out.extend(ch.tiles.iter().map(|t| t.coord));
                    }
                }
                StageKind::Fc(f) => {
                    for col in &f.columns {
                        out.extend(col.tiles.iter().map(|t| t.coord));
                    }
                }
                StageKind::Res(r) => {
                    if let Some(p) = &r.proj {
                        for ch in &p.chains {
                            out.extend(ch.tiles.iter().map(|t| t.coord));
                        }
                    }
                }
                _ => {}
            }
        }
        out
    }

    /// Check every schedule fits the 128-entry hardware table after
    /// run-length compression (see `isa::Schedule::compressed_len`).
    pub fn schedules_fit_hardware(&self) -> bool {
        self.schedules()
            .iter()
            .all(|(_, s)| s.compressed_len() <= crate::consts::SCHEDULE_TABLE_ENTRIES)
    }

    /// Stage index for a given layer index, if the layer got a stage of
    /// its own (fused pools return the conv stage they were fused into).
    pub fn stage_for_layer(&self, layer: usize) -> Option<usize> {
        self.stages
            .iter()
            .position(|s| s.layer == layer)
            .or_else(|| {
                // fused pool: find the conv stage with matching fusion
                self.stages.iter().position(|s| {
                    matches!(&s.kind, StageKind::Conv(c) if c.fused_pool.is_some())
                        && s.layer + 1 == layer
                })
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stage_kinds_have_zero_tiles() {
        let s = Stage {
            layer: 0,
            name: "flat".into(),
            kind: StageKind::Flatten,
        };
        assert_eq!(s.tile_count(), 0);
        let p = Stage {
            layer: 1,
            name: "pool".into(),
            kind: StageKind::Pool(PoolStage {
                max: true,
                kernel: 2,
                stride: 2,
                in_shape: TensorShape::new(4, 8, 8),
                out_shape: TensorShape::new(4, 4, 4),
                dup: 1,
            }),
        };
        assert_eq!(p.tile_count(), 0);
    }
}
