//! The Table IV normalization pipeline, assembled per comparison.
//!
//! Produces, for each of the five comparisons, both sides of the table:
//! the counterpart's published + normalized numbers and Domino's
//! *measured* numbers from our simulator/perfmodel under the substituted
//! CIM array, so the eval harness can print paper-vs-ours rows.

use crate::counterparts::Comparison;
use crate::energy::{energy_of, CimModel, EnergyBreakdown};
use crate::perfmodel::NetworkEstimate;

/// Domino-side measured metrics for one comparison.
#[derive(Clone, Debug)]
pub struct DominoMeasured {
    pub tiles: usize,
    pub chips: usize,
    pub area_mm2: f64,
    /// One-image latency (µs) — comparable to the paper's "execution
    /// time".
    pub exec_us: f64,
    /// Pipelined throughput.
    pub images_per_s: f64,
    pub images_per_s_per_core: f64,
    /// Average power at full pipelined utilisation (W).
    pub power_w: f64,
    pub onchip_data_w: f64,
    pub offchip_data_w: f64,
    pub cim_w: f64,
    /// TOPS/W (= ops per joule).
    pub ce_tops_w: f64,
    /// TOPS/mm².
    pub tops_mm2: f64,
    pub energy_per_image: EnergyBreakdown,
}

/// Compute Domino's measured row from a perfmodel estimate + the
/// substituted CIM model.
///
/// Power model: under layer pipelining every stage processes one image
/// per period, so average power = (energy per image) x (images per
/// second). Ops follow the paper's 2-ops-per-MAC convention.
pub fn measure_domino(
    est: &NetworkEstimate,
    cim: &CimModel,
    total_ops: u64,
) -> DominoMeasured {
    let e = energy_of(&est.counters, cim);
    let img_s = est.images_per_s();
    let power = e.total() * img_s;
    let onchip = e.onchip_data() * img_s;
    let offchip = e.offchip_data() * img_s;
    let cim_w = e.cim * img_s;
    let ce = total_ops as f64 / e.total() / 1e12; // TOPS/W == ops/J /1e12
    let area = crate::energy::area::active_area_mm2(est.total_tiles, est.chips, cim);
    let tops = total_ops as f64 * img_s / 1e12;
    DominoMeasured {
        tiles: est.total_tiles,
        chips: est.chips,
        area_mm2: area,
        exec_us: est.latency_s() * 1e6,
        images_per_s: img_s,
        images_per_s_per_core: est.images_per_s_per_core(),
        power_w: power,
        onchip_data_w: onchip,
        offchip_data_w: offchip,
        cim_w,
        ce_tops_w: ce,
        tops_mm2: tops / area,
        energy_per_image: e,
    }
}

/// A fully-assembled Table IV pair: the comparison spec + our measured
/// Domino row.
#[derive(Clone, Debug)]
pub struct Table4Row {
    pub comparison: Comparison,
    pub measured: DominoMeasured,
}

impl Table4Row {
    /// Our normalized-CE improvement (measured Domino CE over the
    /// counterpart's paper-normalized CE — both at 8 b / 1 V / 45 nm).
    pub fn measured_ce_ratio(&self) -> f64 {
        self.measured.ce_tops_w / self.comparison.counterpart.paper_norm_ce
    }

    /// Our normalized-throughput improvement.
    pub fn measured_throughput_ratio(&self) -> f64 {
        self.measured.tops_mm2 / self.comparison.counterpart.paper_norm_tops_mm2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Compiler;
    use crate::counterparts::all_comparisons;
    use crate::model::zoo;

    #[test]
    fn measured_row_for_vgg11_pair() {
        let comp = all_comparisons()[0];
        let net = zoo::vgg11_cifar();
        let arch = crate::coordinator::ArchConfig::table4(comp.domino.chips);
        let program = Compiler::new(arch).compile_analysis(&net).unwrap();
        let est = crate::perfmodel::estimate(&program).unwrap();
        let cim = comp.domino_cim_model();
        let m = measure_domino(&est, &cim, net.total_ops().unwrap());
        // Domino must beat the counterpart's normalized CE (the paper's
        // headline), and data power must be a minority share.
        assert!(
            m.ce_tops_w > comp.counterpart.paper_norm_ce,
            "CE {} vs norm {}",
            m.ce_tops_w,
            comp.counterpart.paper_norm_ce
        );
        let onchip_share = m.onchip_data_w / m.power_w;
        assert!(
            onchip_share < 0.45,
            "on-chip share {onchip_share} should be minor (paper: 8-32%)"
        );
        let offchip_share = m.offchip_data_w / m.power_w;
        assert!(
            offchip_share < 0.05,
            "off-chip share {offchip_share} should be negligible (paper: 0.1-3%)"
        );
        assert!(m.area_mm2 > 0.0 && m.power_w > 0.0);
        assert!(m.images_per_s > 0.0);
        // throughput headline: with the paper's 5-chip budget Domino
        // beats [9]'s normalized TOPS/mm2
        assert!(
            m.tops_mm2 > comp.counterpart.paper_norm_tops_mm2,
            "tops/mm2 {} vs {}",
            m.tops_mm2,
            comp.counterpart.paper_norm_tops_mm2
        );
    }
}
