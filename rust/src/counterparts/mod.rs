//! The five state-of-the-art CIM accelerators Domino is compared against
//! in Table IV, their published operating points, and the normalization
//! pipeline.
//!
//! Domino "adopts existing CIM arrays to enable flexible substitution"
//! (Section II-D): in each pairwise comparison the Domino deployment
//! hosts the *counterpart's* CIM array technology. We therefore derive,
//! for every comparison, a [`CimModel`] from the counterpart's own
//! published numbers:
//!
//! * **energy/MAC** — the counterpart's normalized CE (8 b / 1 V /
//!   45 nm) gives its whole-system energy per op; multiplying by its
//!   *CIM share* (1 − data-movement share, both printed in Table IV)
//!   isolates the array's contribution:
//!   `j_per_mac = 2 / (CE_norm / cim_share)` (2 ops per MAC).
//! * **array area** — from Table IV's Domino-side active area:
//!   `(area / tiles) − router_area` (clamped to a small positive floor
//!   where the published area is below the router area — see
//!   EXPERIMENTS.md §T4 notes).
//!
//! This is exactly the paper's methodology ("power consumption of CIM is
//! not listed" — it is inherited), made explicit and reproducible.

pub mod normalize;

use crate::energy::scaling::{DesignPoint, normalize_ce, normalize_throughput};
use crate::energy::CimModel;

/// CIM technology class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CimType {
    Sram,
    Reram,
}

/// A counterpart architecture's published Table IV column.
#[derive(Clone, Copy, Debug)]
pub struct Counterpart {
    /// Short key ("jia-isscc21").
    pub key: &'static str,
    /// Citation tag as used in the paper.
    pub cite: &'static str,
    pub cim: CimType,
    /// Workload it is compared on.
    pub model: &'static str,
    pub dataset: &'static str,
    pub tech_nm: u32,
    pub vdd: f64,
    pub freq_mhz: f64,
    /// Weight / activation precision (bits).
    pub b_w: u32,
    pub b_a: u32,
    /// CIM cores (chips x cores as a flat count where known).
    pub cores: usize,
    pub area_mm2: f64,
    /// Execution time per inference (µs); None where the paper prints
    /// "n.a.".
    pub exec_us: Option<f64>,
    pub power_w: f64,
    pub onchip_data_w: Option<f64>,
    pub offchip_data_w: Option<f64>,
    /// Computational efficiency as published (TOPS/W).
    pub ce_tops_w: f64,
    /// Paper's normalized CE (TOPS/W at 8 b / 1 V / 45 nm).
    pub paper_norm_ce: f64,
    pub tops_mm2: f64,
    /// Paper's normalized throughput (TOPS/mm² at 8 b / 45 nm).
    pub paper_norm_tops_mm2: f64,
    pub images_s_core: Option<f64>,
    pub accuracy: Option<f64>,
}

/// The paper's Domino-side row for one comparison (Table IV "Ours").
#[derive(Clone, Copy, Debug)]
pub struct DominoPaperRow {
    pub cores_per_chip: usize,
    pub chips: usize,
    pub area_mm2: f64,
    pub exec_us: f64,
    pub power_w: f64,
    pub onchip_data_w: f64,
    pub offchip_data_w: f64,
    pub ce_tops_w: f64,
    pub tops_mm2: f64,
    pub images_s_core: f64,
    pub accuracy: f64,
}

/// One pairwise comparison: counterpart + the paper's Domino row.
#[derive(Clone, Copy, Debug)]
pub struct Comparison {
    pub counterpart: Counterpart,
    pub domino: DominoPaperRow,
}

impl Counterpart {
    pub fn design_point(&self) -> DesignPoint {
        DesignPoint {
            tech_nm: self.tech_nm,
            vdd: self.vdd,
            b_w: self.b_w,
            b_a: self.b_a,
        }
    }

    /// Fraction of published power spent on data movement (on- +
    /// off-chip); falls back to the class average when a term is "n.a.".
    pub fn data_share(&self) -> f64 {
        let on = self.onchip_data_w.unwrap_or(0.24 * self.power_w);
        let off = self.offchip_data_w.unwrap_or(0.0);
        ((on + off) / self.power_w).clamp(0.05, 0.95)
    }

    /// CIM share of the published power.
    pub fn cim_share(&self) -> f64 {
        1.0 - self.data_share()
    }

    /// Our uniformly recomputed normalized CE (cross-check column).
    pub fn recomputed_norm_ce(&self) -> f64 {
        normalize_ce(self.ce_tops_w, &self.design_point())
    }

    /// Our uniformly recomputed normalized throughput.
    pub fn recomputed_norm_tops_mm2(&self) -> f64 {
        normalize_throughput(self.tops_mm2, &self.design_point())
    }
}

impl Comparison {
    /// The CIM array model Domino adopts for this comparison (see module
    /// docs for the derivation).
    pub fn domino_cim_model(&self) -> CimModel {
        let cim_ce_norm = self.counterpart.paper_norm_ce / self.counterpart.cim_share();
        let j_per_mac = 2.0 / (cim_ce_norm * 1e12);
        let tiles = (self.domino.cores_per_chip * self.domino.chips) as f64;
        let per_tile = self.domino.area_mm2 / tiles;
        let array_area = (per_tile - crate::energy::area::router_area_mm2()).max(0.005);
        CimModel {
            j_per_mac,
            array_area_mm2: array_area,
            label: match self.counterpart.cim {
                CimType::Sram => "SRAM (substituted)",
                CimType::Reram => "ReRAM (substituted)",
            },
        }
    }

    /// The paper's headline normalized-CE improvement for this pair.
    pub fn paper_ce_ratio(&self) -> f64 {
        // Domino's row is already at the reference point, so its CE is
        // its normalized CE.
        self.domino.ce_tops_w / self.counterpart.paper_norm_ce
    }

    /// The paper's normalized-throughput improvement for this pair.
    pub fn paper_throughput_ratio(&self) -> f64 {
        self.domino.tops_mm2 / self.counterpart.paper_norm_tops_mm2
    }
}

/// Table IV, column by column.
pub fn all_comparisons() -> Vec<Comparison> {
    vec![
        // VGG-11 / CIFAR-10 vs Jia et al., ISSCC'21 [9] (SRAM, 16 nm)
        Comparison {
            counterpart: Counterpart {
                key: "jia-isscc21",
                cite: "[9]",
                cim: CimType::Sram,
                model: "vgg11-cifar10",
                dataset: "CIFAR-10",
                tech_nm: 16,
                vdd: 0.8,
                freq_mhz: 200.0,
                b_w: 4,
                b_a: 4,
                cores: 16,
                area_mm2: 17.5,
                exec_us: Some(128.0),
                power_w: 0.15,
                onchip_data_w: Some(0.036),
                offchip_data_w: Some(0.06),
                ce_tops_w: 71.39,
                paper_norm_ce: 9.53,
                tops_mm2: 0.7,
                paper_norm_tops_mm2: 0.088,
                images_s_core: Some(488.0),
                accuracy: Some(91.51),
            },
            domino: DominoPaperRow {
                cores_per_chip: 240,
                chips: 5,
                area_mm2: 343.2,
                exec_us: 137.3,
                power_w: 11.03,
                onchip_data_w: 3.53,
                offchip_data_w: 0.34,
                ce_tops_w: 17.22,
                tops_mm2: 0.55,
                images_s_core: 2604.0,
                accuracy: 89.85,
            },
        },
        // ResNet-18 / CIFAR-10 vs Yue et al., ISSCC'20 [17] (SRAM, 65 nm)
        Comparison {
            counterpart: Counterpart {
                key: "yue-isscc20",
                cite: "[17]",
                cim: CimType::Sram,
                model: "resnet18-cifar10",
                dataset: "CIFAR-10",
                tech_nm: 65,
                vdd: 1.0,
                freq_mhz: 100.0,
                b_w: 4,
                b_a: 4,
                cores: 4,
                area_mm2: 5.68,
                exec_us: Some(1890.0),
                power_w: 2.78e-3,
                onchip_data_w: Some(1.76e-3),
                offchip_data_w: None,
                ce_tops_w: 6.91,
                paper_norm_ce: 2.82,
                tops_mm2: 0.006,
                paper_norm_tops_mm2: 0.013,
                images_s_core: Some(8.0),
                accuracy: Some(91.15),
            },
            domino: DominoPaperRow {
                cores_per_chip: 240,
                chips: 6,
                area_mm2: 655.2,
                exec_us: 206.3,
                power_w: 18.10,
                onchip_data_w: 2.95,
                offchip_data_w: 0.10,
                ce_tops_w: 6.30,
                tops_mm2: 0.17,
                images_s_core: 2604.0,
                accuracy: 91.57,
            },
        },
        // VGG-16 / ImageNet vs Yoon et al., ISSCC'21 [16] (ReRAM, 40 nm)
        Comparison {
            counterpart: Counterpart {
                key: "yoon-isscc21",
                cite: "[16]",
                cim: CimType::Reram,
                model: "vgg16-imagenet",
                dataset: "ImageNet",
                tech_nm: 40,
                vdd: 0.9,
                freq_mhz: 100.0,
                b_w: 8,
                b_a: 8,
                cores: 1,
                area_mm2: 0.44,
                exec_us: Some(670_000.0),
                power_w: 11.05e-3,
                onchip_data_w: Some(1.47e-3),
                offchip_data_w: Some(4.76e-3),
                ce_tops_w: 4.15,
                paper_norm_ce: 3.92,
                tops_mm2: 0.10,
                paper_norm_tops_mm2: 0.081,
                images_s_core: None,
                accuracy: Some(46.0),
            },
            domino: DominoPaperRow {
                cores_per_chip: 240,
                chips: 10,
                area_mm2: 381.6,
                exec_us: 3481.8,
                power_w: 4.26,
                onchip_data_w: 0.64,
                offchip_data_w: 0.005,
                ce_tops_w: 9.29,
                tops_mm2: 0.10,
                images_s_core: 53.0,
                accuracy: 70.71,
            },
        },
        // VGG-19 / ImageNet vs AtomLayer, DAC'18 [10] (ReRAM, 32 nm)
        Comparison {
            counterpart: Counterpart {
                key: "atomlayer-dac18",
                cite: "[10]",
                cim: CimType::Reram,
                model: "vgg19-imagenet",
                dataset: "ImageNet",
                tech_nm: 32,
                vdd: 1.0,
                freq_mhz: 1200.0,
                b_w: 16,
                b_a: 16,
                cores: 160,
                area_mm2: 6.89,
                exec_us: Some(6920.0),
                power_w: 4.8,
                onchip_data_w: Some(0.54),
                offchip_data_w: Some(1.32),
                ce_tops_w: 0.68,
                paper_norm_ce: 2.73,
                tops_mm2: 0.36,
                paper_norm_tops_mm2: 0.18,
                images_s_core: None,
                accuracy: None,
            },
            domino: DominoPaperRow {
                cores_per_chip: 240,
                chips: 10,
                area_mm2: 192.0,
                exec_us: 3582.9,
                power_w: 8.73,
                onchip_data_w: 0.72,
                offchip_data_w: 0.01,
                ce_tops_w: 5.73,
                tops_mm2: 0.22,
                images_s_core: 53.0,
                accuracy: 72.38,
            },
        },
        // VGG-19 / ImageNet vs CASCADE, MICRO'19 [6] (ReRAM, 65 nm)
        Comparison {
            counterpart: Counterpart {
                key: "cascade-micro19",
                cite: "[6]",
                cim: CimType::Reram,
                model: "vgg19-imagenet",
                dataset: "ImageNet",
                tech_nm: 65,
                vdd: 1.0,
                freq_mhz: 1200.0,
                b_w: 16,
                b_a: 16,
                cores: 96, // "80 - 112"
                area_mm2: 0.99,
                exec_us: None,
                power_w: 3.0e-3,
                onchip_data_w: Some(0.7e-3),
                offchip_data_w: Some(0.9e-3),
                ce_tops_w: 1.96,
                paper_norm_ce: 6.18,
                tops_mm2: 0.10,
                paper_norm_tops_mm2: 0.21,
                images_s_core: None,
                accuracy: None,
            },
            domino: DominoPaperRow {
                cores_per_chip: 240,
                chips: 10,
                area_mm2: 125.5,
                exec_us: 3582.9,
                power_w: 4.57,
                onchip_data_w: 0.72,
                offchip_data_w: 0.01,
                ce_tops_w: 10.95,
                tops_mm2: 0.66,
                images_s_core: 53.0,
                accuracy: 72.38,
            },
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_comparisons() {
        assert_eq!(all_comparisons().len(), 5);
    }

    #[test]
    fn paper_headline_ce_ratios() {
        // "Domino achieves 1.77-to-2.37x power efficiency" — the ratios
        // of the published Table IV rows must reproduce the abstract.
        let comps = all_comparisons();
        let ratios: Vec<f64> = comps.iter().map(|c| c.paper_ce_ratio()).collect();
        let min = ratios.iter().cloned().fold(f64::MAX, f64::min);
        let max = ratios.iter().cloned().fold(f64::MIN, f64::max);
        assert!((min - 1.77).abs() < 0.05, "min ratio {min}");
        assert!((max - 2.37).abs() < 0.05, "max ratio {max}");
    }

    #[test]
    fn paper_headline_throughput_ratios() {
        // "...improves the throughput by 1.28-to-13.16x".
        let comps = all_comparisons();
        let ratios: Vec<f64> = comps.iter().map(|c| c.paper_throughput_ratio()).collect();
        let min = ratios.iter().cloned().fold(f64::MAX, f64::min);
        let max = ratios.iter().cloned().fold(f64::MIN, f64::max);
        assert!(min > 1.1 && min < 1.35, "min ratio {min}");
        assert!((max - 13.16).abs() < 0.2, "max ratio {max}");
    }

    #[test]
    fn cim_models_are_physical() {
        for comp in all_comparisons() {
            let cim = comp.domino_cim_model();
            assert!(
                cim.j_per_mac > 0.01e-12 && cim.j_per_mac < 2.0e-12,
                "{}: {} pJ/MAC",
                comp.counterpart.key,
                cim.j_per_mac * 1e12
            );
            assert!(cim.array_area_mm2 > 0.0);
        }
    }

    #[test]
    fn sram_substitution_cheaper_than_reram() {
        let comps = all_comparisons();
        let jia = comps[0].domino_cim_model();
        let yoon = comps[2].domino_cim_model();
        assert!(jia.j_per_mac < yoon.j_per_mac);
    }

    #[test]
    fn data_share_uses_published_fractions() {
        let comps = all_comparisons();
        // [9]: (0.036 + 0.06) / 0.15 = 64%
        assert!((comps[0].counterpart.data_share() - 0.64).abs() < 0.01);
        // [17]: off-chip n.a. -> on-chip only: 1.76/2.78 = 63.3%
        assert!((comps[1].counterpart.data_share() - 0.633).abs() < 0.01);
    }

    #[test]
    fn recomputed_normalization_within_factor_three_of_paper() {
        // Our uniform Stillmaker-Baas pipeline vs the paper's printed
        // normalized values: same order of magnitude for every
        // counterpart (the paper's own rows are not mutually consistent
        // — see EXPERIMENTS.md §T4).
        for comp in all_comparisons() {
            let ours = comp.counterpart.recomputed_norm_ce();
            let theirs = comp.counterpart.paper_norm_ce;
            let ratio = ours / theirs;
            assert!(
                (0.33..3.0).contains(&ratio),
                "{}: ours {ours:.2} vs paper {theirs:.2}",
                comp.counterpart.key
            );
        }
    }
}
