//! L3 performance harness (§Perf): cycle-engine throughput on
//! progressively larger workloads — the optimization target for the
//! performance pass (EXPERIMENTS.md §Perf records before/after).

use domino::benchutil::bench;
use domino::coordinator::Compiler;
use domino::model::{zoo, NetworkBuilder, TensorShape};
use domino::sim::Simulator;
use domino::testutil::Rng;

fn main() {
    println!("L3 engine performance\n");

    // single conv layers of growing size
    for (c, m, h) in [(16usize, 16usize, 16usize), (64, 64, 16), (64, 64, 32), (128, 128, 32)] {
        let net = NetworkBuilder::new("perf", TensorShape::new(c, h, h))
            .conv(m, 3, 1, 1)
            .build();
        let program = Compiler::default().compile(&net).unwrap();
        let mut rng = Rng::new(9);
        let input = rng.i8_vec(net.input_len(), 31);
        let macs = net.total_macs().unwrap();
        let s = bench(
            &format!("conv {c}x{h}x{h} -> {m} ({:.1} MMAC)", macs as f64 / 1e6),
            5,
            || {
                let mut sim = Simulator::new(&program);
                std::hint::black_box(sim.run_image(&input).unwrap());
            },
        );
        println!(
            "{:>56} {:.1} MMAC/s",
            "",
            macs as f64 / s.median.as_secs_f64() / 1e6
        );
    }

    // whole networks
    for name in ["tiny-cnn", "resnet18-cifar10"] {
        let net = zoo::by_name(name).unwrap();
        let program = Compiler::default().compile(&net).unwrap();
        let mut rng = Rng::new(10);
        let input = rng.i8_vec(net.input_len(), 31);
        let macs = net.total_macs().unwrap();
        let s = bench(&format!("{name} full image"), 3, || {
            let mut sim = Simulator::new(&program);
            std::hint::black_box(sim.run_image(&input).unwrap());
        });
        println!(
            "{:>56} {:.1} MMAC/s",
            "",
            macs as f64 / s.median.as_secs_f64() / 1e6
        );
    }

    // compiler throughput
    bench("compile vgg16-imagenet (10-chip, full weights)", 3, || {
        let p = Compiler::new(domino::coordinator::ArchConfig::table4(10))
            .compile(&zoo::vgg16_imagenet())
            .unwrap();
        std::hint::black_box(p);
    });
    bench("compile vgg16-imagenet (10-chip, analysis)", 5, || {
        let p = Compiler::new(domino::coordinator::ArchConfig::table4(10))
            .compile_analysis(&zoo::vgg16_imagenet())
            .unwrap();
        std::hint::black_box(p);
    });
}
