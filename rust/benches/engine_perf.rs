//! L3 performance harness (§Perf): cycle-engine throughput, measured
//! against a **frozen copy of the pre-arena hot path** kept in
//! [`legacy`] below. Every run therefore re-measures the recorded
//! pre-refactor baseline on the same machine, asserts the new engine
//! is bit-exact with it (scores *and* every energy counter), and
//! gates PASS/FAIL on the single-thread `run_image` speedup.
//!
//!     cargo bench --bench engine_perf                      # full run
//!     cargo bench --bench engine_perf -- --smoke           # CI gate leg
//!     cargo bench --bench engine_perf -- --json BENCH_engine.json
//!     cargo bench --bench engine_perf -- --gate 1.5        # override
//!
//! The gate (default ≥2.0x) applies to the zoo's cycle-sim serving
//! models; the process exits non-zero on FAIL so CI can regress on it.

use domino::benchutil::{arg_value, bench, percentile, stats, time_n, JsonObj};
use domino::coordinator::Compiler;
use domino::model::{zoo, NetworkBuilder, TensorShape};
use domino::sim::{CaptureMode, Simulator};
use domino::testutil::Rng;

/// A frozen reimplementation of the pre-arena cycle engine (the PR-3
/// state of `sim::engine`): one owned `Vec<i32>` per psum churned
/// through the FIFOs and register queues, per-pixel `collect()`s in
/// the pool/res/fc loops, allocating activation/quantize calls, a
/// fresh pooling unit per chain per image, and every stage tensor
/// cloned into the output (the old `AllStages`-always behavior,
/// including the final double clone).
///
/// Do not "optimize" this module — it *is* the baseline the bench
/// gates against. It charges exactly the counters the old engine
/// charged, which the harness asserts equal to the new engine's.
mod legacy {
    use std::collections::VecDeque;

    use anyhow::{bail, Result};
    use domino::coordinator::program::*;
    use domino::coordinator::schedule::{ConvGeometry, CYCLES_PER_SLOT};
    use domino::model::refcompute::Tensor;
    use domino::model::TensorShape;
    use domino::noc::packet::PsumPacket;
    use domino::sim::Counters;
    use domino::tile::rofm::{PoolUnit, Rofm};
    use domino::tile::{Pe, Rifm};

    /// Pre-arena per-tile state: an owned-packet FIFO (the old ROFM
    /// buffer model) and an owned-packet register queue.
    struct LTile {
        rifm: Rifm,
        fifo: VecDeque<PsumPacket>,
        fifo_bytes: usize,
        peak_fifo_bytes: usize,
        incoming: VecDeque<PsumPacket>,
        xbuf: Vec<i8>,
    }

    impl LTile {
        fn new(t: &ConvTile) -> Self {
            Self {
                rifm: Rifm::new_with_config(t.rifm),
                fifo: VecDeque::new(),
                fifo_bytes: 0,
                peak_fifo_bytes: 0,
                incoming: VecDeque::new(),
                xbuf: Vec::with_capacity(t.rows),
            }
        }

        fn reset(&mut self) {
            self.incoming.clear();
            self.rifm.reset();
            self.fifo.clear();
            self.fifo_bytes = 0;
            self.peak_fifo_bytes = 0;
            self.xbuf.clear();
        }

        fn push_group(&mut self, p: PsumPacket, st: &mut Counters) {
            self.fifo_bytes += 4 * p.data.len();
            self.peak_fifo_bytes = self.peak_fifo_bytes.max(self.fifo_bytes);
            st.rofm_buffer_accesses += 1;
            st.peak_rofm_buffer_bytes =
                st.peak_rofm_buffer_bytes.max(self.peak_fifo_bytes as u64);
            self.fifo.push_back(p);
        }

        fn pop_group(&mut self, st: &mut Counters) -> Option<PsumPacket> {
            let p = self.fifo.pop_front()?;
            self.fifo_bytes -= 4 * p.data.len();
            st.rofm_buffer_accesses += 1;
            Some(p)
        }
    }

    /// The pre-arena engine: persistent tile state (built once, reset
    /// per image — the PR-1/2/3 design), allocating hot path.
    pub struct Engine {
        state: Vec<Vec<Vec<LTile>>>,
        pub stats: Counters,
    }

    impl Engine {
        pub fn new(program: &Program) -> Self {
            fn conv_state(c: &ConvStage) -> Vec<Vec<LTile>> {
                c.chains
                    .iter()
                    .map(|chain| chain.tiles.iter().map(LTile::new).collect())
                    .collect()
            }
            let state = program
                .stages
                .iter()
                .map(|stage| match &stage.kind {
                    StageKind::Conv(c) => conv_state(c),
                    StageKind::Res(r) => r.proj.as_ref().map(conv_state).unwrap_or_default(),
                    _ => Vec::new(),
                })
                .collect();
            Self {
                state,
                stats: Counters::new(),
            }
        }

        pub fn run_image(&mut self, program: &Program, input: &[i8]) -> Result<RunOut> {
            if input.len() != program.net.input_len() {
                bail!("input length mismatch");
            }
            let mut cur = Tensor::new(program.net.input, input.to_vec());
            let mut stage_outputs: Vec<Tensor> = Vec::with_capacity(program.stages.len());
            let mut total_cycles: u64 = 0;
            self.stats.offchip_io_bits += 8 * input.len() as u64;

            let mut prev_exit_chip: Option<usize> = None;
            for (si, stage) in program.stages.iter().enumerate() {
                let mut st = Counters::new();
                let (out, slots) = match &stage.kind {
                    StageKind::Conv(c) => self.run_conv_stage(program, si, c, &cur, &mut st)?,
                    StageKind::Fc(f) => run_fc_stage(program, f, &cur, &mut st)?,
                    StageKind::Pool(p) => run_pool_stage(p, &cur, &mut st)?,
                    StageKind::Res(r) => {
                        let skip_src = &stage_outputs[r.from_stage];
                        let skip = match &r.proj {
                            Some(pstage) => {
                                let (t, s2) =
                                    self.run_conv_stage(program, si, pstage, skip_src, &mut st)?;
                                total_cycles += s2 * CYCLES_PER_SLOT as u64;
                                t
                            }
                            None => skip_src.clone(),
                        };
                        run_res_stage(r, &cur, &skip, &mut st)?
                    }
                    StageKind::Flatten => {
                        let t = Tensor::new(
                            TensorShape::new(cur.shape.len(), 1, 1),
                            cur.data.clone(),
                        );
                        (t, 0)
                    }
                };
                let entry = stage_entry_chip(stage);
                if let (Some(prev), Some(this)) = (prev_exit_chip, entry) {
                    if prev != this {
                        st.interchip_bits += 8 * cur.shape.len() as u64;
                    }
                }
                prev_exit_chip = stage_exit_chip(stage).or(prev_exit_chip);

                st.steps += slots * CYCLES_PER_SLOT as u64;
                st.tiles_used += stage.tile_count() as u64;
                total_cycles += slots * CYCLES_PER_SLOT as u64;
                self.stats.merge(&st);
                stage_outputs.push(out.clone());
                cur = out;
            }
            self.stats.offchip_io_bits += 8 * cur.data.len() as u64;

            Ok(RunOut {
                scores: cur.data.clone(),
                latency_cycles: total_cycles,
            })
        }

        fn run_conv_stage(
            &mut self,
            program: &Program,
            si: usize,
            c: &ConvStage,
            input: &Tensor,
            st: &mut Counters,
        ) -> Result<(Tensor, u64)> {
            assert_eq!(input.shape, c.in_shape, "conv stage input shape");
            let g = ConvGeometry::new(c.k, c.stride, c.padding, c.in_shape.h, c.in_shape.w);
            let wp = g.wp();
            let total_pixels = wp * g.hp();

            let mut conv_out = Tensor::zeros(c.out_shape);
            let mut pool_out_shape = c.out_shape;
            if let Some(p) = c.fused_pool {
                pool_out_shape = TensorShape::new(
                    c.out_shape.c,
                    (c.out_shape.h - p.kernel) / p.stride + 1,
                    (c.out_shape.w - p.kernel) / p.stride + 1,
                );
            }
            let mut pooled = Tensor::zeros(pool_out_shape);

            let chains_rt = &mut self.state[si];
            for (chain, tiles) in c.chains.iter().zip(chains_rt.iter_mut()) {
                // old behavior: a fresh pooling unit per chain per image
                let mut pool = c.fused_pool.map(|p| {
                    if p.max {
                        PoolUnit::new_max(p.kernel, p.stride)
                    } else {
                        PoolUnit::new_avg(p.kernel, p.stride)
                    }
                });
                for t in tiles.iter_mut() {
                    t.reset();
                }
                let n = tiles.len();
                let m_lanes = chain.m_hi - chain.m_lo;

                for slot in 0..(total_pixels + n) {
                    for ci in 0..n {
                        let Some(p) = slot.checked_sub(ci) else { continue };
                        if p >= total_pixels {
                            continue;
                        }
                        let cfg = &chain.tiles[ci];
                        let (pr, u) = (p / wp, p % wp);
                        let pack = match cfg.rifm.shift_step {
                            64 => 4,
                            128 => 2,
                            _ => 1,
                        };
                        let bits = (cfg.rows * 8) as u64;
                        if p % pack == 0 {
                            st.rifm_buffer_accesses += 1;
                            st.rifm_ctrl_steps += 1;
                            if cfg.rifm.forward {
                                let cross = ci + 1 < n
                                    && chain.tiles[ci + 1].coord.chip != cfg.coord.chip;
                                if cross {
                                    st.interchip_bits += bits * pack as u64;
                                } else {
                                    st.onchip_link_bits += bits * pack as u64;
                                }
                            }
                        } else {
                            st.rifm_shifts += 1;
                        }
                        st.sched_fetches += CYCLES_PER_SLOT as u64;
                        st.rofm_ctrl_steps += CYCLES_PER_SLOT as u64;

                        let (py, px) = (
                            pr as isize - c.padding as isize,
                            u as isize - c.padding as isize,
                        );
                        let c_lo = cfg.cb * program.arch.n_c;
                        let (Some(oy), Some(ox)) =
                            (g.out_row(pr, cfg.kr), g.out_col(u, cfg.kc))
                        else {
                            continue;
                        };

                        let rt = &mut tiles[ci];
                        rt.xbuf.clear();
                        rt.xbuf
                            .extend((0..cfg.rows).map(|dc| input.at_padded(c_lo + dc, py, px)));
                        // the pre-arena hot path: every MVM allocates
                        let mac =
                            Pe::borrowed(&cfg.weights, cfg.rows, cfg.cols).mvm(&rt.xbuf, st);
                        let opos = (oy, ox);

                        let mut psum = if cfg.is_chain_start {
                            PsumPacket { opos, data: mac }
                        } else {
                            let prev = if cfg.is_row_head {
                                tiles[ci].pop_group(st)
                            } else {
                                tiles[ci].incoming.pop_front()
                            };
                            let Some(mut prev) = prev else {
                                bail!("legacy engine: missing psum (schedule bug)");
                            };
                            if prev.opos != opos {
                                bail!("legacy engine: psum tag mismatch");
                            }
                            let own = PsumPacket { opos, data: mac };
                            Rofm::add_psum(&mut prev, &own, st);
                            prev
                        };
                        psum.opos = opos;

                        if cfg.is_last {
                            let vals = if c.relu {
                                Rofm::act(&psum.data, c.shift, st)
                            } else {
                                Rofm::quantize(&psum.data, c.shift, st)
                            };
                            for (lane, &v) in vals.iter().enumerate() {
                                conv_out.set(chain.m_lo + lane, oy, ox, v);
                            }
                            if let Some(unit) = pool.as_mut() {
                                for ((poy, pox), pv) in unit.offer(opos, &vals, st) {
                                    for (lane, &v) in pv.iter().enumerate() {
                                        pooled.set(chain.m_lo + lane, poy, pox, v);
                                    }
                                }
                            }
                            let obits = (m_lanes * 8) as u64;
                            Rofm::charge_tx(obits, st);
                            st.onchip_link_bits += obits;
                        } else {
                            let pbits = (psum.data.len() * 32) as u64;
                            Rofm::charge_tx(pbits, st);
                            if chain.tiles[ci + 1].coord.chip != cfg.coord.chip {
                                st.interchip_bits += pbits;
                            } else {
                                st.onchip_link_bits += pbits;
                            }
                            if chain.tiles[ci + 1].is_row_head {
                                tiles[ci + 1].push_group(psum, st);
                            } else {
                                Rofm::charge_rx(pbits, st);
                                tiles[ci + 1].incoming.push_back(psum);
                            }
                        }
                    }
                }
                for t in tiles.iter() {
                    if !t.incoming.is_empty() || !t.fifo.is_empty() {
                        bail!("legacy engine: chain undrained");
                    }
                }
            }

            let out = if c.fused_pool.is_some() {
                pooled
            } else {
                conv_out
            };
            let n = c.chains.iter().map(|ch| ch.tiles.len()).max().unwrap_or(0) as u64;
            let slots = (total_pixels as u64).div_ceil(c.dup as u64) + n;
            Ok((out, slots))
        }
    }

    /// Scores + latency of one legacy run (stage tensors are cloned
    /// internally exactly as the old engine did, then dropped).
    pub struct RunOut {
        pub scores: Vec<i8>,
        pub latency_cycles: u64,
    }

    fn run_fc_stage(
        program: &Program,
        f: &FcStage,
        input: &Tensor,
        st: &mut Counters,
    ) -> Result<(Tensor, u64)> {
        if input.shape.len() != f.in_features {
            bail!("fc stage input mismatch");
        }
        let mut out = vec![0i8; f.out_features];
        let mut max_slot = 0u64;
        for col in &f.columns {
            let mut acc: Option<PsumPacket> = None;
            for (rb, t) in col.tiles.iter().enumerate() {
                let i_lo = rb * program.arch.n_c;
                let x: Vec<i8> = (0..t.rows).map(|d| input.data[i_lo + d]).collect();
                st.rifm_buffer_accesses += 1;
                st.rifm_ctrl_steps += 1;
                st.sched_fetches += 1;
                st.rofm_ctrl_steps += 1;
                st.onchip_link_bits += (t.rows * 8) as u64;
                let pe = Pe::borrowed(&t.weights, t.rows, t.cols);
                let mac = pe.mvm(&x, st);
                let own = PsumPacket {
                    opos: (0, col.cblock),
                    data: mac,
                };
                acc = Some(match acc.take() {
                    None => own,
                    Some(mut prev) => {
                        let pbits = (prev.data.len() * 32) as u64;
                        if rb > 0 && col.tiles[rb - 1].coord.chip != t.coord.chip {
                            st.interchip_bits += pbits;
                        } else {
                            st.onchip_link_bits += pbits;
                        }
                        Rofm::charge_rx(pbits, st);
                        Rofm::add_psum(&mut prev, &own, st);
                        prev
                    }
                });
                max_slot = max_slot.max((rb + 1) as u64);
            }
            let acc = acc.expect("fc column has tiles");
            let vals = if f.relu {
                Rofm::act(&acc.data, f.shift, st)
            } else {
                Rofm::quantize(&acc.data, f.shift, st)
            };
            let obits = (vals.len() * 8) as u64;
            Rofm::charge_tx(obits, st);
            st.onchip_link_bits += obits;
            out[col.c_lo..col.c_hi].copy_from_slice(&vals);
        }
        Ok((
            Tensor::new(TensorShape::new(f.out_features, 1, 1), out),
            max_slot + 1,
        ))
    }

    fn run_pool_stage(p: &PoolStage, input: &Tensor, st: &mut Counters) -> Result<(Tensor, u64)> {
        assert_eq!(input.shape, p.in_shape, "pool stage input shape");
        let mut unit = if p.max {
            PoolUnit::new_max(p.kernel, p.stride)
        } else {
            PoolUnit::new_avg(p.kernel, p.stride)
        };
        let mut out = Tensor::zeros(p.out_shape);
        let mut slots = 0u64;
        for y in 0..input.shape.h {
            for x in 0..input.shape.w {
                let vals: Vec<i8> = (0..input.shape.c).map(|ch| input.at(ch, y, x)).collect();
                let bits = (vals.len() * 8) as u64;
                st.onchip_link_bits += bits;
                Rofm::charge_rx(bits, st);
                st.sched_fetches += 1;
                st.rofm_ctrl_steps += 1;
                for ((oy, ox), pv) in unit.offer((y, x), &vals, st) {
                    for (ch, &v) in pv.iter().enumerate() {
                        out.set(ch, oy, ox, v);
                    }
                }
                slots += 1;
            }
        }
        Ok((out, slots.div_ceil(p.dup as u64)))
    }

    fn run_res_stage(
        r: &ResStage,
        main: &Tensor,
        skip: &Tensor,
        st: &mut Counters,
    ) -> Result<(Tensor, u64)> {
        if main.shape != skip.shape {
            bail!("res stage shape mismatch");
        }
        assert_eq!(main.shape, r.shape);
        let mut out = Tensor::zeros(main.shape);
        let mut slots = 0u64;
        for y in 0..main.shape.h {
            for x in 0..main.shape.w {
                let a: Vec<i8> = (0..main.shape.c).map(|ch| main.at(ch, y, x)).collect();
                let b: Vec<i8> = (0..main.shape.c).map(|ch| skip.at(ch, y, x)).collect();
                let bits = (b.len() * 8) as u64;
                st.onchip_link_bits += bits;
                let bypassed = Rofm::bypass(&b, st);
                st.sched_fetches += 1;
                st.rofm_ctrl_steps += 1;
                let v = Rofm::res_add(&a, &bypassed, st);
                for (ch, &vv) in v.iter().enumerate() {
                    out.set(ch, y, x, vv);
                }
                slots += 1;
            }
        }
        Ok((out, slots.div_ceil(r.dup as u64)))
    }

    fn stage_entry_chip(stage: &Stage) -> Option<usize> {
        match &stage.kind {
            StageKind::Conv(c) => c.chains.first()?.tiles.first().map(|t| t.coord.chip),
            StageKind::Fc(f) => f.columns.first()?.tiles.first().map(|t| t.coord.chip),
            StageKind::Res(r) => r
                .proj
                .as_ref()
                .and_then(|p| p.chains.first()?.tiles.first().map(|t| t.coord.chip)),
            _ => None,
        }
    }

    fn stage_exit_chip(stage: &Stage) -> Option<usize> {
        match &stage.kind {
            StageKind::Conv(c) => c.chains.last()?.tiles.last().map(|t| t.coord.chip),
            StageKind::Fc(f) => f.columns.last()?.tiles.last().map(|t| t.coord.chip),
            StageKind::Res(r) => r
                .proj
                .as_ref()
                .and_then(|p| p.chains.last()?.tiles.last().map(|t| t.coord.chip)),
            _ => None,
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let json_path = arg_value(&argv, "--json");
    let gate: f64 = arg_value(&argv, "--gate")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2.0);
    println!(
        "L3 engine performance ({}) — arena engine vs frozen pre-arena baseline, \
         gate >= {gate:.2}x\n",
        if smoke { "smoke" } else { "full" }
    );

    let mut workload_json: Vec<String> = Vec::new();
    let mut all_pass = true;

    // ---- single conv layers of growing size (reference curve; the
    // pass/fail gate runs on the zoo models below) --------------------
    if !smoke {
        for (c, m, h) in [(16usize, 16usize, 16usize), (64, 64, 16), (64, 64, 32), (128, 128, 32)]
        {
            let net = NetworkBuilder::new("perf", TensorShape::new(c, h, h))
                .conv(m, 3, 1, 1)
                .build();
            let program = Compiler::default().compile(&net).unwrap();
            let mut rng = Rng::new(9);
            let input = rng.i8_vec(net.input_len(), 31);
            let macs = net.total_macs().unwrap();
            let mut sim = Simulator::with_capture(&program, CaptureMode::Final);
            let s = bench(
                &format!("conv {c}x{h}x{h} -> {m} ({:.1} MMAC)", macs as f64 / 1e6),
                5,
                || {
                    std::hint::black_box(sim.run_image(&input).unwrap());
                },
            );
            println!(
                "{:>56} {:.1} MMAC/s",
                "",
                macs as f64 / s.median.as_secs_f64() / 1e6
            );
        }
        println!();
    }

    // ---- the gate: zoo cycle-sim models, legacy vs arena engine -----
    let mut models = vec!["tiny-cnn", "tiny-mlp", "tiny-resnet"];
    if !smoke {
        models.push("resnet18-cifar10");
    }
    for name in models {
        let net = zoo::by_name(name).unwrap();
        let program = Compiler::default().compile(&net).unwrap();
        let mut rng = Rng::new(10);
        let macs = net.total_macs().unwrap();
        // Timer-noise amortization: tiny models simulate in
        // microseconds, so each timed iteration runs a pool of
        // distinct images and reported times are per image.
        let pool_n = if name == "resnet18-cifar10" { 2 } else { 8 };
        let pool: Vec<Vec<i8>> = (0..pool_n)
            .map(|_| rng.i8_vec(net.input_len(), 31))
            .collect();
        let inner = pool.len() as u32;

        // Correctness first: the arena engine must be bit-exact with
        // the pre-refactor path — scores AND every energy counter
        // (counters are the energy model's input).
        {
            let mut lg = legacy::Engine::new(&program);
            let lg_out = lg.run_image(&program, &pool[0]).unwrap();
            let mut fresh = Simulator::with_capture(&program, CaptureMode::Final);
            let new_out = fresh.run_image(&pool[0]).unwrap();
            assert_eq!(
                lg_out.scores, new_out.scores,
                "{name}: arena engine diverged from the pre-refactor baseline"
            );
            assert_eq!(
                lg_out.latency_cycles, new_out.latency_cycles,
                "{name}: latency diverged"
            );
            assert_eq!(
                &lg.stats,
                fresh.stats(),
                "{name}: counters diverged from the pre-refactor baseline"
            );

            // The fault seam must be invisible when empty: an engine
            // threaded with an empty FaultInjector must be
            // bit-identical to the NoFaults engine — scores, latency,
            // and every counter. On divergence, both runs repeat
            // under a flight recorder and the first divergent event
            // (tile, slot, kind) is printed via flight::diff, so the
            // regression is located, not merely detected.
            use domino::sim::{flight, FaultInjector, FaultPlan, FlightRecorder, RecorderConfig};
            let mut faulty = Simulator::with_faults(&program, FaultPlan::default());
            faulty.set_capture(CaptureMode::Final);
            let f_out = faulty.run_image(&pool[0]).unwrap();
            let identical = f_out.scores == new_out.scores
                && f_out.latency_cycles == new_out.latency_cycles
                && faulty.stats() == fresh.stats();
            if !identical {
                let mut rec_clean =
                    Simulator::with_recorder(&program, RecorderConfig::default());
                rec_clean.set_capture(CaptureMode::Final);
                rec_clean.run_image(&pool[0]).unwrap();
                let mut rec_faulty = Simulator::with_instruments(
                    &program,
                    FlightRecorder::new(RecorderConfig::default()),
                    FaultInjector::new(FaultPlan::default()),
                );
                rec_faulty.set_capture(CaptureMode::Final);
                rec_faulty.run_image(&pool[0]).unwrap();
                let d = flight::diff(&rec_clean.recording(), &rec_faulty.recording());
                eprintln!("{}", d.render());
                panic!("{name}: empty fault plan diverged from the NoFaults engine");
            }
        }

        let iters = if name == "resnet18-cifar10" {
            3
        } else if smoke {
            5
        } else {
            7
        };
        let mut lg = legacy::Engine::new(&program);
        let base = stats(
            time_n(iters, || {
                for img in &pool {
                    std::hint::black_box(lg.run_image(&program, img).unwrap());
                }
            })
            .into_iter()
            .map(|d| d / inner)
            .collect(),
        );
        println!(
            "{name:<24} baseline (pre-arena): {:>10.3?}/img  ({:.1} MMAC/s)",
            base.median,
            macs as f64 / base.median.as_secs_f64() / 1e6
        );

        let mut sim = Simulator::with_capture(&program, CaptureMode::Final);
        let steady_samples: Vec<std::time::Duration> = time_n(iters, || {
            for img in &pool {
                std::hint::black_box(sim.run_image(img).unwrap());
            }
        })
        .into_iter()
        .map(|d| d / inner)
        .collect();
        let steady = stats(steady_samples.clone());
        let speedup = steady.speedup_over(&base);
        let pass = speedup >= gate;
        all_pass &= pass;
        println!(
            "{name:<24} arena engine:         {:>10.3?}/img  ({:.1} MMAC/s, {speedup:.2}x) {}",
            steady.median,
            macs as f64 / steady.median.as_secs_f64() / 1e6,
            if pass { "PASS" } else { "FAIL" }
        );

        // The percentiles are over per-iteration means (each sample is
        // one pass over the image pool, divided by the pool size) —
        // timer-noise spread, NOT per-request tail latency like the
        // serve bench's; the basis is recorded alongside them.
        let mut w = JsonObj::new();
        w.str_field("name", name)
            .u64_field("macs", macs)
            .u64_field("image_pool", inner as u64)
            .u64_field("iters", iters as u64)
            .str_field(
                "percentile_basis",
                "per-iteration mean over the image pool (run-to-run spread, not request tail latency)",
            )
            .f64_field("baseline_s", base.median.as_secs_f64())
            .f64_field("steady_s", steady.median.as_secs_f64())
            .f64_field("images_per_s", steady.per_second(1))
            .f64_field(
                "p50_us",
                percentile(&steady_samples, 50.0).as_secs_f64() * 1e6,
            )
            .f64_field(
                "p95_us",
                percentile(&steady_samples, 95.0).as_secs_f64() * 1e6,
            )
            .f64_field(
                "p99_us",
                percentile(&steady_samples, 99.0).as_secs_f64() * 1e6,
            )
            .f64_field("speedup_vs_baseline", speedup)
            .bool_field("pass", pass);
        workload_json.push(w.finish());
    }

    // ---- compiler throughput (unchanged reference numbers) ----------
    if !smoke {
        println!();
        bench("compile vgg16-imagenet (10-chip, full weights)", 3, || {
            let p = Compiler::new(domino::coordinator::ArchConfig::table4(10))
                .compile(&zoo::vgg16_imagenet())
                .unwrap();
            std::hint::black_box(p);
        });
        bench("compile vgg16-imagenet (10-chip, analysis)", 5, || {
            let p = Compiler::new(domino::coordinator::ArchConfig::table4(10))
                .compile_analysis(&zoo::vgg16_imagenet())
                .unwrap();
            std::hint::black_box(p);
        });
    }

    println!(
        "\nsingle-thread run_image speedup gate (>= {gate:.2}x vs pre-arena baseline): {}",
        if all_pass { "PASS" } else { "FAIL" }
    );

    if let Some(path) = json_path {
        let mut doc = JsonObj::new();
        doc.str_field("bench", "engine_perf")
            .str_field("mode", if smoke { "smoke" } else { "full" })
            .f64_field("gate", gate)
            .bool_field("pass", all_pass)
            .raw_field("workloads", &domino::benchutil::json_array(&workload_json));
        domino::benchutil::write_json(&path, &doc.finish()).expect("write bench json");
    }

    if !all_pass {
        eprintln!("engine_perf: speedup gate FAILED");
        std::process::exit(1);
    }
}
