//! Experiment A3 — validate the closed-form analytic model against the
//! cycle-accurate engine: counters must be *exactly* equal, and the
//! analytic path must be orders of magnitude faster (that's why
//! Table IV's full-size networks use it).

use domino::benchutil::{bench, stats, time_n};
use domino::coordinator::Compiler;
use domino::model::zoo;
use domino::sim::Simulator;
use domino::testutil::Rng;

fn main() {
    println!("A3 — analytic perfmodel vs cycle engine\n");
    for name in ["tiny-cnn"] {
        let net = zoo::by_name(name).unwrap();
        let program = Compiler::default().compile(&net).unwrap();
        let est = domino::perfmodel::estimate(&program).unwrap();
        let mut sim = Simulator::new(&program);
        let mut rng = Rng::new(3);
        let out = sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
        let s = sim.stats();
        let checks = [
            ("pe_macs", est.counters.pe_macs, s.pe_macs),
            ("rifm_buffer", est.counters.rifm_buffer_accesses, s.rifm_buffer_accesses),
            ("adds_8b", est.counters.adds_8b, s.adds_8b),
            ("onchip_bits", est.counters.onchip_link_bits, s.onchip_link_bits),
            ("rofm_buffer", est.counters.rofm_buffer_accesses, s.rofm_buffer_accesses),
            ("latency", est.latency_cycles, out.latency_cycles),
        ];
        println!("{name}:");
        for (k, a, b) in checks {
            let err = if a == b { "exact" } else { "MISMATCH" };
            println!("  {k:<14} analytic {a:>12} engine {b:>12}  {err}");
            assert_eq!(a, b, "{k}");
        }
    }

    println!();
    let net = zoo::tiny_cnn();
    let program = Compiler::default().compile(&net).unwrap();
    let mut rng = Rng::new(4);
    let input = rng.i8_vec(net.input_len(), 31);
    let engine = stats(time_n(5, || {
        let mut sim = Simulator::new(&program);
        std::hint::black_box(sim.run_image(&input).unwrap());
    }));
    let analytic = stats(time_n(50, || {
        std::hint::black_box(domino::perfmodel::estimate(&program).unwrap());
    }));
    println!(
        "tiny-cnn: engine {:?} vs analytic {:?} per evaluation ({}x)",
        engine.median,
        analytic.median,
        engine.median.as_nanos() / analytic.median.as_nanos().max(1)
    );

    // the analytic model makes Table IV tractable:
    bench("a3: analytic estimate of vgg16-imagenet", 10, || {
        let p = Compiler::default().compile_analysis(&zoo::vgg16_imagenet()).unwrap();
        std::hint::black_box(domino::perfmodel::estimate(&p).unwrap());
    });
}
