//! Experiment F4 — paper Fig. 4: pooling by weight duplication vs block
//! reuse, swept over the Table IV workloads. Duplication buys a shorter
//! stage period (4x output rate before pools) at K_p² x the tiles.

use domino::baselines::pooling;
use domino::benchutil::bench;
use domino::energy::CimModel;
use domino::model::zoo;

fn main() {
    println!("FIG. 4 — pooling schemes (block reuse vs weight duplication)\n");
    println!(
        "{:<18} {:>22} {:>22} {:>10} {:>10}",
        "model", "block-reuse t/period", "weight-dup t/period", "tiles x", "speedup"
    );
    let cim = CimModel::generic_sram();
    for (net, _) in zoo::table4_workloads() {
        let ab = pooling::ablate(&net, &cim).unwrap();
        println!(
            "{:<18} {:>10} / {:>9} {:>10} / {:>9} {:>9.2}x {:>9.2}x",
            net.name,
            ab.block_reuse.tiles,
            ab.block_reuse.period_cycles,
            ab.weight_dup.tiles,
            ab.weight_dup.period_cycles,
            ab.tile_ratio(),
            ab.speedup()
        );
    }
    println!();
    let net = zoo::vgg11_cifar();
    bench("fig4: both schemes, vgg11", 10, || {
        std::hint::black_box(pooling::ablate(&net, &cim).unwrap());
    });
}
