//! Calibration-sensitivity ablation: sweep the one free constant (the
//! Noxim-derived on-chip link energy) and show the headline results do
//! not depend on the chosen value — see EXPERIMENTS.md §Calibration.

use domino::benchutil::bench;
use domino::eval::sensitivity::{render, sweep, DEFAULT_GRID};

fn main() {
    let rows = sweep(&DEFAULT_GRID).expect("sweep");
    print!("{}", render(&rows));
    println!();
    bench("link-energy sweep (5 points x 5 workloads)", 5, || {
        std::hint::black_box(sweep(&DEFAULT_GRID).unwrap());
    });
}
