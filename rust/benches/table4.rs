//! Experiment T4 — regenerate the paper's Table IV (all five pairwise
//! comparisons) and time the full harness (compile + analytic model +
//! energy pricing for every workload).

use domino::benchutil::bench;
use domino::eval::table4;

fn main() {
    let entries = table4::run().expect("table4");
    print!("{}", table4::render(&entries));
    println!();
    bench("table4: full 5-comparison harness", 5, || {
        let e = table4::run().unwrap();
        std::hint::black_box(e);
    });
}
