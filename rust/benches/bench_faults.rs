//! Fault-plane bench: what deterministic fault injection costs and
//! how fast the serve plane recovers from it, recorded into
//! `BENCH_faults.json`.
//!
//!     cargo bench --bench bench_faults                     # full run
//!     cargo bench --bench bench_faults -- --smoke          # CI leg
//!     cargo bench --bench bench_faults -- --json BENCH_faults.json
//!
//! Two halves:
//!
//! * **Engine sweep** — per fault kind (dead tile, stuck-at tile,
//!   link bit-flip, dropped-flit, slot-windowed transient), the
//!   armed engine's per-image throughput next to the clean engine's,
//!   plus what actually fired (fires, corrupted psum lanes) and the
//!   output verdict against the clean run. The empty-plan row is the
//!   seam's own overhead: an armed-but-empty injector must track the
//!   NoFaults engine closely (and stays bit-exact — `engine_perf`
//!   gates that).
//! * **Serve recovery** — the end-to-end drill through a real
//!   `Service`: clean throughput, detection latency (`FaultInject`'s
//!   seeded diagnostic), throughput while serving silently-corrupt
//!   responses, heal latency (`Canary {heal}` = canary + masked
//!   re-map + verifying canary), and post-heal throughput with every
//!   response checked bit-exact against refcompute.
//!
//! Correctness violations (a heal that does not heal, a post-heal
//! response that is not bit-exact) exit non-zero; timing numbers are
//! recorded but not gated.

use std::sync::Arc;

use domino::benchutil::{arg_value, stats, time_n, JsonObj};
use domino::coordinator::{ArchConfig, Compiler};
use domino::model::zoo;
use domino::serve::api::{Dispatcher, Request, Response};
use domino::serve::{ModelRegistry, ServeConfig, Server, Service};
use domino::sim::fault::corruption_verdict;
use domino::sim::{CaptureMode, FaultPlan, Simulator};
use domino::testutil::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let json_path = arg_value(&argv, "--json");
    println!(
        "fault-plane bench ({}) — injection overhead + detect/heal recovery\n",
        if smoke { "smoke" } else { "full" }
    );

    let mut violations = 0usize;
    let mut engine_json: Vec<String> = Vec::new();

    // ---- engine sweep: per-kind cost and blast radius ----------------
    let sweep_models: &[&str] = if smoke {
        &["tiny-cnn"]
    } else {
        &["tiny-cnn", "tiny-resnet"]
    };
    let iters = if smoke { 3 } else { 7 };
    for name in sweep_models {
        let net = zoo::by_name(name).unwrap();
        let program = Compiler::default().compile(&net).unwrap();
        let mut rng = Rng::new(0xFA);
        let input = rng.i8_vec(net.input_len(), 31);
        let coords = program.tile_coords();
        let (c0, c1) = (coords[0], coords[coords.len() / 2]);

        let mut clean = Simulator::with_capture(&program, CaptureMode::Final);
        let clean_out = clean.run_image(&input).unwrap();
        let base = stats(time_n(iters, || {
            std::hint::black_box(clean.run_image(&input).unwrap());
        }));
        println!(
            "{name:<14} {:<22} {:>10.3?}/img",
            "clean (NoFaults)", base.median
        );

        let plans: Vec<(&str, FaultPlan)> = vec![
            ("empty plan", FaultPlan::default()),
            ("dead tile", FaultPlan::new().dead_tile(c0)),
            ("stuck-at tile", FaultPlan::new().stuck_tile(c0, 7)),
            ("link bit-flip", FaultPlan::new().link_flip(c1, 3)),
            ("link dropped-flit", FaultPlan::new().link_drop(c1)),
            (
                "transient (slots 0-32)",
                FaultPlan::new().stuck_tile(c0, 7).during(0, 32),
            ),
        ];
        for (kind, plan) in plans {
            let mut sim = Simulator::with_faults(&program, plan);
            sim.set_capture(CaptureMode::Final);
            let out = sim.run_image(&input).unwrap();
            let verdict = corruption_verdict(&out.scores, &clean_out.scores);
            let t = stats(time_n(iters, || {
                std::hint::black_box(sim.run_image(&input).unwrap());
            }));
            let report = sim.fault_report();
            let overhead = t.median.as_secs_f64() / base.median.as_secs_f64();
            println!(
                "{name:<14} {kind:<22} {:>10.3?}/img  ({overhead:.2}x clean)  \
                 fires {} lanes {}  {}",
                t.median,
                report.total_fires(),
                report.total_lanes(),
                if verdict.corrupted {
                    format!("{}/{} outputs wrong", verdict.mismatched, verdict.outputs)
                } else {
                    "outputs clean".to_string()
                }
            );
            let mut w = JsonObj::new();
            w.str_field("model", name)
                .str_field("kind", kind)
                .f64_field("clean_s_per_img", base.median.as_secs_f64())
                .f64_field("faulty_s_per_img", t.median.as_secs_f64())
                .f64_field("overhead_vs_clean", overhead)
                .u64_field("fires", report.total_fires())
                .u64_field("lanes_corrupted", report.total_lanes())
                .bool_field("corrupted", verdict.corrupted)
                .u64_field("outputs_wrong", verdict.mismatched as u64)
                .u64_field("outputs_total", verdict.outputs as u64);
            engine_json.push(w.finish());
        }
        println!();
    }

    // ---- serve recovery: detect -> degrade -> re-map -> verify -------
    const MODEL: &str = "tiny-mlp";
    const SEED: u64 = 42;
    let n = if smoke { 8 } else { 32 };

    let registry = Arc::new(ModelRegistry::new());
    let server = Server::start_multi(
        ServeConfig {
            workers: 2,
            max_batch: 4,
            queue_cap: 64,
            ..ServeConfig::default()
        },
        registry,
    )
    .expect("start server");
    let service = Service::new(server, ArchConfig::default());
    let stamp = match service.dispatch(Request::LoadSeeded {
        model: MODEL.to_string(),
        seed: SEED,
        mapping: None,
    }) {
        Response::Loaded(stamp) => stamp,
        other => panic!("load failed: {other:?}"),
    };
    let reg = service.server().registry().expect("sim registry");
    let mv = reg.get(&stamp.name).expect("loaded model");
    let ilen = mv.input_len();
    let bad = mv.program().tile_coords()[0];
    let mut rng = Rng::new(0xFA_2);
    let images: Vec<Vec<i8>> = (0..n).map(|_| rng.i8_vec(ilen, 31)).collect();
    let expected: Vec<Vec<i8>> = images.iter().map(|i| mv.refcompute(i).unwrap()).collect();

    let infer_all = |label: &str, check: bool| -> (f64, usize) {
        let t0 = std::time::Instant::now();
        let mut wrong = 0usize;
        for (i, img) in images.iter().enumerate() {
            match service.dispatch(Request::Infer {
                model: Some(MODEL.to_string()),
                image: img.clone(),
            }) {
                Response::Infer(r) => {
                    if r.logits != expected[i] {
                        wrong += 1;
                        assert!(
                            !check,
                            "{label}: response {i} not bit-exact after recovery"
                        );
                    }
                }
                other => panic!("{label}: infer {i} failed: {other:?}"),
            }
        }
        (n as f64 / t0.elapsed().as_secs_f64(), wrong)
    };

    let (clean_rps, _) = infer_all("clean", true);
    println!("serve {MODEL}: clean {clean_rps:.0} req/s over {n} requests");

    let plan = FaultPlan::new().stuck_tile(bad, 7).spec();
    let t_detect = std::time::Instant::now();
    let rep = match service.dispatch(Request::FaultInject {
        model: MODEL.to_string(),
        plan,
    }) {
        Response::Fault(rep) => rep,
        other => panic!("fault inject failed: {other:?}"),
    };
    let detect_us = t_detect.elapsed().as_micros() as u64;
    println!(
        "armed stuck-at on tile {bad}: diagnostic {} fire(s), {}/{} outputs wrong, \
         detected in {detect_us} us",
        rep.fires, rep.mismatched, rep.outputs
    );
    if !rep.corrupted {
        eprintln!("fault-plane bench: diagnostic saw no corruption — nothing to recover from");
        violations += 1;
    }

    let (faulty_rps, wrong_under_fault) = infer_all("under-fault", false);
    println!(
        "under fault: {faulty_rps:.0} req/s, {wrong_under_fault}/{n} responses silently wrong \
         (all structurally valid)"
    );

    let t_heal = std::time::Instant::now();
    let canary = match service.dispatch(Request::Canary {
        model: MODEL.to_string(),
        seed: 0xCA11A2,
        heal: true,
    }) {
        Response::Canary(c) => c,
        other => panic!("canary heal failed: {other:?}"),
    };
    let heal_us = t_heal.elapsed().as_micros() as u64;
    println!(
        "heal: canary {} -> remapped {} healed {} (v{}) in {heal_us} us",
        if canary.ok { "PASS" } else { "FAIL" },
        canary.remapped,
        canary.healed,
        canary.version
    );
    if !(canary.remapped && canary.healed) {
        eprintln!("fault-plane bench: heal failed to recover the model");
        violations += 1;
    }

    let (healed_rps, _) = infer_all("post-heal", true);
    println!("post-heal: {healed_rps:.0} req/s, all {n} responses bit-exact (v{})", canary.version);

    service.shutdown().expect("shutdown");

    if let Some(path) = json_path {
        let mut serve_json = JsonObj::new();
        serve_json
            .str_field("model", MODEL)
            .u64_field("requests_per_phase", n as u64)
            .f64_field("clean_req_per_s", clean_rps)
            .f64_field("under_fault_req_per_s", faulty_rps)
            .f64_field("post_heal_req_per_s", healed_rps)
            .u64_field("detect_us", detect_us)
            .u64_field("heal_us", heal_us)
            .u64_field("diag_fires", rep.fires)
            .u64_field("wrong_under_fault", wrong_under_fault as u64)
            .bool_field("healed", canary.remapped && canary.healed)
            .u64_field("healed_version", canary.version);
        let mut doc = JsonObj::new();
        doc.str_field("bench", "faults")
            .str_field("mode", if smoke { "smoke" } else { "full" })
            .bool_field("pass", violations == 0)
            .raw_field("engine", &domino::benchutil::json_array(&engine_json))
            .raw_field("serve", &serve_json.finish());
        domino::benchutil::write_json(&path, &doc.finish()).expect("write bench json");
    }

    if violations > 0 {
        eprintln!("bench_faults: {violations} correctness violation(s)");
        std::process::exit(1);
    }
    println!("\nfault-plane bench: PASS");
}
