//! Experiment A2 — the RIFM in-buffer shift: first layers have few
//! input channels, so several pixel beats pack into one 256 B buffer
//! row ("the in-buffer shifting architecture maximizes in-tile data
//! reuse when handling the first few layers with small input channel
//! numbers"). Ablation: disable the shift (pack = 1) and compare
//! first-layer RIFM traffic and energy.

use domino::coordinator::program::StageKind;
use domino::coordinator::Compiler;
use domino::energy::{energy_of, CimModel};
use domino::model::zoo;

fn main() {
    println!("A2 — RIFM in-buffer shift ablation (first-layer stream)\n");
    println!(
        "{:<18} {:>16} {:>16} {:>12} {:>14}",
        "model", "beats w/ shift", "beats w/o", "RIFM uJ w/", "RIFM uJ w/o"
    );
    for (net, _) in zoo::table4_workloads() {
        let with = Compiler::default().compile_analysis(&net).unwrap();
        let mut without = with.clone();
        for s in &mut without.stages {
            if let StageKind::Conv(c) = &mut s.kind {
                for ch in &mut c.chains {
                    for t in &mut ch.tiles {
                        t.rifm.shift_step = 0; // disable packing
                    }
                }
            }
        }
        let ew = domino::perfmodel::estimate(&with).unwrap();
        let eo = domino::perfmodel::estimate(&without).unwrap();
        let cim = CimModel::generic_sram();
        let jw = energy_of(&ew.counters, &cim);
        let jo = energy_of(&eo.counters, &cim);
        println!(
            "{:<18} {:>16} {:>16} {:>12.3} {:>14.3}",
            net.name,
            ew.counters.rifm_buffer_accesses,
            eo.counters.rifm_buffer_accesses,
            1e6 * (jw.rifm_buffer + jw.rifm_shift),
            1e6 * (jo.rifm_buffer + jo.rifm_shift),
        );
        assert!(ew.counters.rifm_buffer_accesses < eo.counters.rifm_buffer_accesses);
    }
    println!("\n(beats drop ~4x on C=3 input layers: pack = 256/64)");
}
