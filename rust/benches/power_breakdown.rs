//! Experiment PB — Section IV-B-3 power breakdown per Table IV
//! workload (on-chip 8-32 %, off-chip 0.1-3 % in the paper).

use domino::benchutil::bench;
use domino::eval::breakdown;

fn main() {
    let rows = breakdown::run().expect("breakdown");
    print!("{}", breakdown::render(&rows));
    println!();
    bench("breakdown: all workloads", 5, || {
        std::hint::black_box(breakdown::run().unwrap());
    });
}
