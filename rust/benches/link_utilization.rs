//! Experiment N1 (Noxim substitution) — flit-level link utilization of
//! the COM schedule per workload, and the dual-router vs single-router
//! comparison that motivates the paper's tile structure (contribution
//! 1: "dual routers for different usages").

use domino::benchutil::bench;
use domino::coordinator::Compiler;
use domino::model::zoo;
use domino::noc::flit::{dual_router_report, program_flows, simulate_flits};

fn main() {
    println!("N1 — link utilization of the COM schedule (40 Gb/s links)\n");
    println!(
        "{:<18} {:>8} {:>12} {:>12} {:>16} {:>10}",
        "model", "flows", "RIFM peak", "ROFM peak", "single-router", "verdict"
    );
    for (net, _) in zoo::table4_workloads() {
        let p = Compiler::default().compile_analysis(&net).unwrap();
        let flows = program_flows(&p);
        let r = dual_router_report(&flows);
        let verdict = if r.single_router.peak_utilization > 1.0 {
            "dual req'd"
        } else {
            "fits"
        };
        println!(
            "{:<18} {:>8} {:>11.1}% {:>11.1}% {:>15.1}% {:>10}",
            net.name,
            flows.len(),
            100.0 * r.rifm.peak_utilization,
            100.0 * r.rofm.peak_utilization,
            100.0 * r.single_router.peak_utilization,
            verdict
        );
    }

    println!("\nflit-accurate wormhole simulation (tiny-cnn, 40 steps):");
    let p = Compiler::default().compile(&zoo::tiny_cnn()).unwrap();
    let flows: Vec<_> = program_flows(&p)
        .into_iter()
        .filter(|f| f.src.chip == 0 && f.dst.chip == 0)
        .collect();
    let r = simulate_flits(&flows, 15, 16, 40);
    println!(
        "  {} flits delivered, {} dropped, mean latency {:.1} cycles, \
         max {} cycles, peak queue {} flits",
        r.flits_delivered,
        r.flits_dropped_at_injection,
        r.mean_latency,
        r.max_latency,
        r.peak_queue
    );

    println!();
    bench("n1: vgg16 dual-router analysis", 5, || {
        let p = Compiler::default().compile_analysis(&zoo::vgg16_imagenet()).unwrap();
        std::hint::black_box(dual_router_report(&program_flows(&p)));
    });
    bench("n1: tiny-cnn flit sim 40 steps", 5, || {
        std::hint::black_box(simulate_flits(&flows, 15, 16, 40));
    });
}
