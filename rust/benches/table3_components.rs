//! Experiment T3 — Table III: per-component energy and area of the
//! Domino tile, plus the derived continuous-activity power at the
//! 10 MHz step frequency (sanity: matches the paper's "configuration
//! information summary").

use domino::energy::table3;

fn main() {
    println!("TABLE III — component energy/area (45 nm, 1 V, 10 MHz)\n");
    println!(
        "{:<22} {:>14} {:>16} {:>16}",
        "component", "energy/event", "area (um2)", "P @10MHz duty=1"
    );
    let rows: &[(&str, f64, f64)] = &[
        ("RIFM buffer 256B", table3::RIFM_BUFFER_J, 826.5),
        ("RIFM control", table3::RIFM_CTRL_J, 1400.6),
        ("ROFM adder 8bx8x2", table3::ADDER_8B_J, 0.07),
        ("ROFM pooling 8bx8", table3::POOL_8B_J, 34.06),
        ("ROFM activation 8bx8", table3::ACT_8B_J, 7.07),
        ("ROFM data buf 16KiB", table3::ROFM_BUFFER_J, 52896.0),
        ("ROFM sched 16bx128", table3::SCHED_16B_J, 826.5),
        ("ROFM in buf 64bx2", table3::IOBUF_64B_J, 878.9),
        ("ROFM out buf 64bx2", table3::IOBUF_64B_J, 878.9),
        ("ROFM control", table3::ROFM_CTRL_J, 2451.2),
    ];
    for (name, e, a) in rows {
        println!(
            "{name:<22} {:>11.4} pJ {:>13.2} um2 {:>13.3} mW",
            1e12 * e,
            a,
            1e3 * e * domino::consts::STEP_HZ
        );
    }
    println!(
        "{:<22} {:>11.4} pJ/b (8 x 80 Gb/s transceivers)",
        "inter-chip link",
        1e12 * table3::INTERCHIP_J_PER_BIT
    );
    println!(
        "{:<22} {:>11.4} pJ/b/hop (Noxim-derived, calibrated)",
        "on-chip mesh link",
        1e12 * domino::energy::ONCHIP_LINK_J_PER_BIT
    );
    use domino::energy::area::table3_um2 as a;
    println!(
        "\nper-tile router area: RIFM {:.1} + ROFM {:.1} um2 = {:.4} mm2",
        a::RIFM_TOTAL,
        a::ROFM_TOTAL,
        domino::energy::area::router_area_mm2()
    );
}
