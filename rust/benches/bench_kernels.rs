//! Kernel-level performance harness (§Perf): the blocked i8 compute
//! kernels measured against a **frozen copy of the PR-9 scalar
//! kernels** kept in [`legacy`] below (the same trick as
//! `engine_perf`'s frozen pre-arena engine, one layer down). Every run
//! re-measures the recorded scalar baseline on the same machine,
//! asserts the blocked kernels are bit-exact with it (outputs *and*
//! every charged counter), and gates PASS/FAIL on the single-thread
//! MVM-family speedup.
//!
//!     cargo bench --bench bench_kernels                      # full run
//!     cargo bench --bench bench_kernels -- --smoke           # CI gate leg
//!     cargo bench --bench bench_kernels -- --json BENCH_kernels.json
//!     cargo bench --bench bench_kernels -- --gate 1.2        # override
//!
//! The gate (default ≥1.5x) is the geometric mean over the MVM-family
//! workloads — the panel kernel is where the blocked layout pays. The
//! vectorized rofm datapaths are asserted bit-exact and *reported*
//! (their scalar forms already autovectorize well, so their speedups
//! are informational, not gated); the JSON records the gate basis. The
//! process exits non-zero on FAIL so CI can regress on it.

use domino::benchutil::{arg_value, stats, time_n, JsonObj};
use domino::sim::Counters;
use domino::testutil::Rng;
use domino::tile::pe::MICRO_BATCH;
use domino::tile::rofm::Rofm;
use domino::tile::Pe;

/// Frozen PR-9 scalar kernels — the pre-blocking state of
/// `tile::pe::Pe::mvm_into`, `tile::rofm`'s datapaths and the
/// `refcompute` requant helpers they call, copied verbatim so the
/// baseline cannot drift when the live crate changes.
///
/// Do not "optimize" this module — it *is* the baseline the bench
/// gates against. It charges exactly the counters the scalar kernels
/// charged, which the harness asserts equal to the blocked kernels'.
mod legacy {
    use domino::sim::Counters;

    fn clamp_i8(v: i32) -> i8 {
        v.clamp(i8::MIN as i32, i8::MAX as i32) as i8
    }

    fn requant(acc: i32, shift: u32, relu: bool) -> i8 {
        let mut v = acc >> shift; // arithmetic shift (i32)
        if relu {
            v = v.max(0);
        }
        clamp_i8(v)
    }

    fn res_add(a: i8, b: i8) -> i8 {
        clamp_i8((a as i32 + b as i32).max(0))
    }

    /// The PR-9 `Pe::mvm_into` body over a row-major `[rows][cols]`
    /// weight slice: per-row zero skip, scalar inner accumulation.
    pub fn mvm_into(
        weights: &[i8],
        rows: usize,
        cols: usize,
        x: &[i8],
        out: &mut [i32],
        stats: &mut Counters,
    ) {
        assert!(x.len() <= rows, "input vector exceeds crossbar rows");
        assert_eq!(out.len(), cols, "MVM output width");
        stats.pe_mvms += 1;
        stats.pe_macs += (x.len() * cols) as u64;
        out.fill(0);
        for (c, &xv) in x.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i32;
            let row = &weights[c * cols..(c + 1) * cols];
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += xv * wv as i32;
            }
        }
    }

    /// The PR-9 `Rofm::add_psum_slices` body.
    pub fn add_psum_slices(acc: &mut [i32], incoming: &[i32], stats: &mut Counters) {
        assert_eq!(acc.len(), incoming.len(), "psum width mismatch");
        for (a, b) in acc.iter_mut().zip(incoming.iter()) {
            *a += b;
        }
        stats.adds_8b += 4 * acc.len() as u64;
    }

    /// The PR-9 `Rofm::act_into` body.
    pub fn act_into(sum: &[i32], shift: u32, out: &mut Vec<i8>, stats: &mut Counters) {
        stats.act_ops_8b += sum.len() as u64;
        out.clear();
        out.extend(sum.iter().map(|&v| requant(v, shift, true)));
    }

    /// The PR-9 `Rofm::quantize_into` body.
    pub fn quantize_into(sum: &[i32], shift: u32, out: &mut Vec<i8>, stats: &mut Counters) {
        stats.act_ops_8b += sum.len() as u64;
        out.clear();
        out.extend(sum.iter().map(|&v| requant(v, shift, false)));
    }

    /// The PR-9 `Rofm::cmp_max` body.
    pub fn cmp_max(acc: &mut [i8], incoming: &[i8], stats: &mut Counters) {
        assert_eq!(acc.len(), incoming.len());
        stats.pool_ops_8b += acc.len() as u64;
        for (a, b) in acc.iter_mut().zip(incoming.iter()) {
            *a = (*a).max(*b);
        }
    }

    /// The PR-9 `Rofm::res_add_into` body.
    pub fn res_add_into(main: &[i8], skip: &[i8], out: &mut Vec<i8>, stats: &mut Counters) {
        assert_eq!(main.len(), skip.len());
        stats.adds_8b += main.len() as u64;
        stats.act_ops_8b += main.len() as u64;
        out.clear();
        out.extend(main.iter().zip(skip.iter()).map(|(&a, &b)| res_add(a, b)));
    }
}

/// An i8 input vector with roughly `zero_pct`% zeros (a post-ReLU
/// activation profile — the zero-skip paths in both kernels see the
/// same mix, so the comparison is fair).
fn sparse_vec(rng: &mut Rng, len: usize, zero_pct: f64) -> Vec<i8> {
    (0..len)
        .map(|_| if rng.chance(zero_pct / 100.0) { 0 } else { rng.i8() })
        .collect()
}

/// One measured workload row: a bit-exactness check, then timed
/// baseline and blocked runs.
struct Row {
    name: String,
    speedup: f64,
    baseline_s: f64,
    steady_s: f64,
    gated: bool,
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let json_path = arg_value(&argv, "--json");
    let gate: f64 = arg_value(&argv, "--gate")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.5);
    println!(
        "kernel performance ({}) — blocked kernels vs frozen PR-9 scalar baseline, \
         MVM geomean gate >= {gate:.2}x\n",
        if smoke { "smoke" } else { "full" }
    );
    println!(
        "note: BENCH_*.json files checked into the repo are schema seeds, not \
         measured numbers (see ROADMAP standing note)\n"
    );

    let iters = if smoke { 5 } else { 7 };
    let mut rows: Vec<Row> = Vec::new();

    // ---- MVM family (the gate basis) --------------------------------
    // (rows, cols, %zeros in x): dense square, post-ReLU sparse, and a
    // cols ∤ LANE remainder-panel shape.
    let mvm_shapes: &[(usize, usize, f64, &str)] = &[
        (256, 256, 0.0, "mvm dense 256x256"),
        (256, 256, 50.0, "mvm sparse50 256x256"),
        (256, 100, 0.0, "mvm remainder 256x100"),
    ];
    let reps = if smoke { 64 } else { 256 };
    for &(r, c, zp, name) in mvm_shapes {
        let mut rng = Rng::new(11);
        let weights = sparse_vec(&mut rng, r * c, 0.0);
        let xs: Vec<Vec<i8>> = (0..8).map(|_| sparse_vec(&mut rng, r, zp)).collect();
        let pe = Pe::new(weights.clone(), r, c);
        let mut out_a = vec![0i32; c];
        let mut out_b = vec![0i32; c];

        // Correctness first: outputs AND charged counters must match.
        let (mut st_a, mut st_b) = (Counters::default(), Counters::default());
        for x in &xs {
            legacy::mvm_into(&weights, r, c, x, &mut out_a, &mut st_a);
            pe.mvm_into(x, &mut out_b, &mut st_b);
            assert_eq!(out_a, out_b, "{name}: blocked MVM diverged from scalar");
        }
        assert_eq!(st_a, st_b, "{name}: counters diverged");

        let mut st = Counters::default();
        let base = stats(time_n(iters, || {
            for i in 0..reps {
                legacy::mvm_into(&weights, r, c, &xs[i % xs.len()], &mut out_a, &mut st);
            }
            std::hint::black_box(&out_a);
        }));
        let steady = stats(time_n(iters, || {
            for i in 0..reps {
                pe.mvm_into(&xs[i % xs.len()], &mut out_b, &mut st);
            }
            std::hint::black_box(&out_b);
        }));
        push_row(&mut rows, name, &base, &steady, true, (r * c * reps) as u64);
    }

    // mvm_many_into: one packed mount draining a full micro-batch vs
    // MICRO_BATCH separate scalar MVMs (the conv-chain refill shape).
    {
        let (r, c) = (256usize, 256usize);
        let name = format!("mvm_many x{MICRO_BATCH} 256x256");
        let mut rng = Rng::new(12);
        let weights = sparse_vec(&mut rng, r * c, 0.0);
        let batch: Vec<Vec<i8>> = (0..MICRO_BATCH).map(|_| sparse_vec(&mut rng, r, 30.0)).collect();
        let xs: Vec<&[i8]> = batch.iter().map(|v| v.as_slice()).collect();
        let pe = Pe::new(weights.clone(), r, c);
        let mut out_a = vec![0i32; MICRO_BATCH * c];
        let mut out_b = vec![0i32; MICRO_BATCH * c];

        let (mut st_a, mut st_b) = (Counters::default(), Counters::default());
        for (b, x) in xs.iter().enumerate() {
            legacy::mvm_into(&weights, r, c, x, &mut out_a[b * c..(b + 1) * c], &mut st_a);
        }
        pe.mvm_many_into(&xs, &mut out_b, &mut st_b);
        assert_eq!(out_a, out_b, "{name}: micro-batch MVM diverged from scalar");
        assert_eq!(st_a, st_b, "{name}: counters diverged");

        let mut st = Counters::default();
        let base = stats(time_n(iters, || {
            for _ in 0..reps {
                for (b, x) in xs.iter().enumerate() {
                    legacy::mvm_into(&weights, r, c, x, &mut out_a[b * c..(b + 1) * c], &mut st);
                }
            }
            std::hint::black_box(&out_a);
        }));
        let steady = stats(time_n(iters, || {
            for _ in 0..reps {
                pe.mvm_many_into(&xs, &mut out_b, &mut st);
            }
            std::hint::black_box(&out_b);
        }));
        let macs = (r * c * MICRO_BATCH * reps) as u64;
        push_row(&mut rows, &name, &base, &steady, true, macs);
    }

    // ---- vectorized rofm datapaths (reported, not gated) ------------
    let vreps = if smoke { 1024 } else { 4096 };
    {
        let len = 256usize;
        let mut rng = Rng::new(13);
        let inc: Vec<i32> = (0..len).map(|_| rng.i8() as i32 * 117).collect();
        let sum: Vec<i32> = (0..len).map(|_| rng.i8() as i32 * 33).collect();
        let main_v = sparse_vec(&mut rng, len, 20.0);
        let skip_v = sparse_vec(&mut rng, len, 20.0);
        let mut acc_a = vec![0i32; len];
        let mut acc_b = vec![0i32; len];
        let mut v8_a: Vec<i8> = Vec::new();
        let mut v8_b: Vec<i8> = Vec::new();

        // Correctness first, for every reported datapath.
        let (mut st_a, mut st_b) = (Counters::default(), Counters::default());
        legacy::add_psum_slices(&mut acc_a, &inc, &mut st_a);
        Rofm::add_psum_slices(&mut acc_b, &inc, &mut st_b);
        assert_eq!(acc_a, acc_b, "add_psum_slices diverged");
        legacy::act_into(&sum, 4, &mut v8_a, &mut st_a);
        Rofm::act_into(&sum, 4, &mut v8_b, &mut st_b);
        assert_eq!(v8_a, v8_b, "act_into diverged");
        legacy::quantize_into(&sum, 4, &mut v8_a, &mut st_a);
        Rofm::quantize_into(&sum, 4, &mut v8_b, &mut st_b);
        assert_eq!(v8_a, v8_b, "quantize_into diverged");
        legacy::res_add_into(&main_v, &skip_v, &mut v8_a, &mut st_a);
        Rofm::res_add_into(&main_v, &skip_v, &mut v8_b, &mut st_b);
        assert_eq!(v8_a, v8_b, "res_add_into diverged");
        let mut mx_a = main_v.clone();
        let mut mx_b = main_v.clone();
        legacy::cmp_max(&mut mx_a, &skip_v, &mut st_a);
        Rofm::cmp_max(&mut mx_b, &skip_v, &mut st_b);
        assert_eq!(mx_a, mx_b, "cmp_max diverged");
        assert_eq!(st_a, st_b, "rofm datapath counters diverged");

        let mut st = Counters::default();
        let base = stats(time_n(iters, || {
            for _ in 0..vreps {
                legacy::add_psum_slices(&mut acc_a, &inc, &mut st);
                legacy::act_into(&sum, 4, &mut v8_a, &mut st);
                legacy::res_add_into(&main_v, &skip_v, &mut v8_a, &mut st);
                legacy::cmp_max(&mut mx_a, &skip_v, &mut st);
            }
            std::hint::black_box((&acc_a, &v8_a, &mx_a));
        }));
        let steady = stats(time_n(iters, || {
            for _ in 0..vreps {
                Rofm::add_psum_slices(&mut acc_b, &inc, &mut st);
                Rofm::act_into(&sum, 4, &mut v8_b, &mut st);
                Rofm::res_add_into(&main_v, &skip_v, &mut v8_b, &mut st);
                Rofm::cmp_max(&mut mx_b, &skip_v, &mut st);
            }
            std::hint::black_box((&acc_b, &v8_b, &mx_b));
        }));
        let ops = (4 * len * vreps) as u64;
        push_row(&mut rows, "rofm psum/act/res/cmp 256", &base, &steady, false, ops);
    }

    // ---- the gate: geometric mean over the MVM family ---------------
    let gated: Vec<&Row> = rows.iter().filter(|r| r.gated).collect();
    let geomean = (gated.iter().map(|r| r.speedup.ln()).sum::<f64>() / gated.len() as f64).exp();
    let pass = geomean >= gate;
    println!(
        "\nMVM-family kernel speedup gate (geomean >= {gate:.2}x vs frozen scalar): \
         {geomean:.2}x {}",
        if pass { "PASS" } else { "FAIL" }
    );

    if let Some(path) = json_path {
        let workloads: Vec<String> = rows
            .iter()
            .map(|r| {
                let mut w = JsonObj::new();
                w.str_field("name", &r.name)
                    .f64_field("baseline_s", r.baseline_s)
                    .f64_field("steady_s", r.steady_s)
                    .f64_field("speedup_vs_scalar", r.speedup)
                    .bool_field("gated", r.gated);
                w.finish()
            })
            .collect();
        let mut doc = JsonObj::new();
        doc.str_field("bench", "bench_kernels")
            .str_field("mode", if smoke { "smoke" } else { "full" })
            .f64_field("gate", gate)
            .str_field(
                "gate_basis",
                "geomean of speedup_vs_scalar over gated (MVM-family) workloads",
            )
            .f64_field("geomean_speedup", geomean)
            .bool_field("pass", pass)
            .raw_field("workloads", &domino::benchutil::json_array(&workloads));
        domino::benchutil::write_json(&path, &doc.finish()).expect("write bench json");
    }

    if !pass {
        eprintln!("bench_kernels: MVM speedup gate FAILED");
        std::process::exit(1);
    }
}

/// Record and print one workload row (ops = total MACs or 8-bit ops
/// per timed iteration, for the throughput column).
fn push_row(
    rows: &mut Vec<Row>,
    name: &str,
    base: &domino::benchutil::Stats,
    steady: &domino::benchutil::Stats,
    gated: bool,
    ops: u64,
) {
    let speedup = steady.speedup_over(base);
    println!(
        "{name:<28} scalar {:>10.3?}  blocked {:>10.3?}  ({:.1} Mop/s, {speedup:.2}x{})",
        base.median,
        steady.median,
        ops as f64 / steady.median.as_secs_f64() / 1e6,
        if gated { "" } else { ", not gated" }
    );
    rows.push(Row {
        name: name.to_string(),
        speedup,
        baseline_s: base.median.as_secs_f64(),
        steady_s: steady.median.as_secs_f64(),
        gated,
    });
}
