//! Experiment A1 — COM dataflow vs the conventional WS + im2col
//! baseline on identical networks and CIM arrays: how much data
//! movement does computing-on-the-move remove? (The paper's Section
//! III claim, quantified per workload.)

use domino::baselines::ws_im2col;
use domino::benchutil::bench;
use domino::counterparts::all_comparisons;
use domino::eval::compile_comparison;

fn main() {
    println!("A1 — data movement: WS+im2col baseline vs COM (same MACs)\n");
    println!(
        "{:<18} {:>16} {:>16} {:>12} {:>12}",
        "workload", "COM on-chip uJ", "im2col on-chip uJ", "movement x", "total x"
    );
    for comp in all_comparisons() {
        let program = compile_comparison(&comp).unwrap();
        let cim = comp.domino_cim_model();
        let ab = ws_im2col::ablate(&program, &cim).unwrap();
        println!(
            "{:<18} {:>16.2} {:>17.2} {:>11.1}x {:>11.2}x",
            comp.counterpart.model,
            1e6 * ab.com.onchip_data(),
            1e6 * ab.baseline.onchip_data(),
            ab.movement_ratio(),
            ab.total_ratio()
        );
    }
    println!();
    let comp = all_comparisons().remove(0);
    let program = compile_comparison(&comp).unwrap();
    let cim = comp.domino_cim_model();
    bench("a1: vgg11 ablation", 10, || {
        std::hint::black_box(ws_im2col::ablate(&program, &cim).unwrap());
    });
}
