//! Serving throughput on the cycle-simulator backend, end to end:
//!
//! 1. `Simulator::run_batch` scaling — an 8-image batch, 1 vs N
//!    threads, bit-exactness asserted against sequential `run_image`
//!    and the wall-clock speedup printed (the PR's ≥2x-on-4-threads
//!    acceptance gate);
//! 2. a closed-loop load test of the `serve` bounded-queue /
//!    micro-batch loop with the [`Server::start_sim`] backend —
//!    concurrent clients, p50/p95/p99 latency, served images/s, and a
//!    bit-exact cross-check of every response against
//!    `model::refcompute`;
//! 3. a **multi-model** closed loop: several models loaded into one
//!    `ModelRegistry`, concurrent clients interleaving requests across
//!    all of them through per-worker engine pools, one model
//!    hot-swapped (fresh weights) mid-traffic — every response is
//!    verified bit-for-bit against refcompute for the exact model
//!    *version* stamped on it, and zero requests may drop or fail;
//! 4. the same mixed-model load driven over the **remote path**: a
//!    `serve::net` TCP endpoint on an ephemeral port, concurrent
//!    `serve::client::Client` connections, a mid-traffic hot-swap and
//!    the final unload issued remotely through the typed admin plane —
//!    every remote response cross-checked against the refcompute of
//!    its stamped model version, plus the per-model `Stats` split;
//! 5. the **cluster** plane: two spawned `domino serve` backend
//!    processes behind a `serve::cluster::Router`, mixed-model
//!    traffic with one backend SIGKILLed mid-run (zero client-visible
//!    drops, bit-exact failover), and the protocol-v2 pipelining gate
//!    (window-8 submit/await on one connection must beat the
//!    one-in-flight client by ≥ 2x at equal request count).
//!
//!     cargo bench --bench serve_sim_throughput            # full run
//!     cargo bench --bench serve_sim_throughput -- --smoke # CI-sized
//!     # CI multi-model leg (router path only, ≥2 models):
//!     cargo bench --bench serve_sim_throughput -- --smoke --multi-only \
//!         --models tiny-cnn,tiny-mlp
//!     # CI remote-protocol leg (TCP path only):
//!     cargo bench --bench serve_sim_throughput -- --smoke --remote-only
//!     # CI cluster smoke leg (spawned backends + router + kill):
//!     cargo bench --bench serve_sim_throughput -- --smoke --cluster-only \
//!         --models tiny-cnn,tiny-mlp
//!
//! `--models a,b,c` picks the loaded set (default
//! `tiny-cnn,tiny-mlp,tiny-resnet`). `--json PATH` additionally writes
//! the run's numbers (images/s, p50/p95/p99, run_batch speedups) as a
//! machine-readable `BENCH_serve.json` so the perf trajectory is
//! recorded run over run.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use domino::benchutil::{arg_value, json_array, stats, time_n, write_json, JsonObj};
use domino::coordinator::ArchConfig;
use domino::model::refcompute::{forward, Tensor};
use domino::model::zoo;
use domino::serve::client::Client;
use domino::serve::net::NetServer;
use domino::serve::{
    sim_program, LatencyStats, ModelRegistry, ModelVersion, ServeConfig, Server, Service,
};
use domino::sim::{CaptureMode, Simulator};
use domino::testutil::Rng;

/// Refcompute reference outputs for `images` under a specific model
/// version's weights.
fn expected_for(mv: &ModelVersion, images: &[Vec<i8>]) -> anyhow::Result<Vec<Vec<i8>>> {
    images.iter().map(|img| mv.refcompute(img)).collect()
}

/// One section's record for the `--json` report.
fn section_json(name: &str, served: usize, secs: f64, lat: &LatencyStats) -> String {
    let mut o = JsonObj::new();
    o.str_field("section", name)
        .u64_field("requests", served as u64)
        .f64_field(
            "images_per_s",
            domino::sim::stats::safe_rate(served as f64, secs),
        )
        .u64_field("p50_us", lat.percentile(50.0).unwrap_or(0))
        .u64_field("p95_us", lat.percentile(95.0).unwrap_or(0))
        .u64_field("p99_us", lat.percentile(99.0).unwrap_or(0));
    o.finish()
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let multi_only = argv.iter().any(|a| a == "--multi-only");
    let remote_only = argv.iter().any(|a| a == "--remote-only");
    let cluster_only = argv.iter().any(|a| a == "--cluster-only");
    let json_path = arg_value(&argv, "--json");
    let mut sections: Vec<String> = Vec::new();
    let mut gate_failures: Vec<String> = Vec::new();
    let model_list = arg_value(&argv, "--models")
        .unwrap_or_else(|| "tiny-cnn,tiny-mlp,tiny-resnet".to_string());
    println!(
        "serve_sim_throughput ({}{}{}{})\n",
        if smoke { "smoke" } else { "full" },
        if multi_only { ", multi-only" } else { "" },
        if remote_only { ", remote-only" } else { "" },
        if cluster_only { ", cluster-only" } else { "" }
    );
    let mut rng = Rng::new(0xBEEF);

    if !multi_only && !remote_only && !cluster_only {
        let net = zoo::tiny_cnn();
        let (program, weights) = sim_program(&net, ArchConfig::default())?;

        // ---- 1. run_batch scaling ------------------------------------
        let batch_n = if smoke { 4 } else { 8 };
        let iters = if smoke { 1 } else { 3 };
        let inputs: Vec<Vec<i8>> = (0..batch_n)
            .map(|_| rng.i8_vec(net.input_len(), 31))
            .collect();

        // sequential reference (also the exactness oracle); the
        // throughput paths run `CaptureMode::Final` — what serving uses
        let mut seq_sim = Simulator::with_capture(&program, CaptureMode::Final);
        let seq_scores: Vec<Vec<i8>> = inputs
            .iter()
            .map(|x| seq_sim.run_image(x).map(|o| o.scores))
            .collect::<anyhow::Result<_>>()?;
        let seq_stats = stats(time_n(iters, || {
            let mut sim = Simulator::with_capture(&program, CaptureMode::Final);
            for x in &inputs {
                std::hint::black_box(sim.run_image(x).unwrap());
            }
        }));
        println!(
            "{batch_n}-image batch, sequential run_image:   {:>10.3?} ({:.1} img/s)",
            seq_stats.median,
            seq_stats.per_second(batch_n)
        );

        let hw = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let mut thread_counts = vec![1usize, 2, 4];
        if hw > 4 {
            thread_counts.push(hw);
        }
        let mut speedup_at_4 = None;
        let mut scaling_json: Vec<String> = Vec::new();
        for threads in thread_counts {
            // exactness first: every batched output must equal sequential
            let mut sim = Simulator::with_capture(&program, CaptureMode::Final);
            let out = sim.run_batch_threads(&inputs, threads)?;
            for (i, (o, want)) in out.outputs.iter().zip(&seq_scores).enumerate() {
                assert_eq!(o.scores, *want, "image {i} diverged at {threads} threads");
            }
            let st = stats(time_n(iters, || {
                let mut sim = Simulator::with_capture(&program, CaptureMode::Final);
                std::hint::black_box(sim.run_batch_threads(&inputs, threads).unwrap());
            }));
            let speedup = st.speedup_over(&seq_stats);
            println!(
                "{batch_n}-image batch, run_batch x{threads:>2} threads: {:>10.3?} \
                 ({:.1} img/s, {speedup:.2}x vs sequential, bit-exact)",
                st.median,
                st.per_second(batch_n)
            );
            let mut o = JsonObj::new();
            o.u64_field("threads", threads as u64)
                .f64_field("images_per_s", st.per_second(batch_n))
                .f64_field("speedup_vs_sequential", speedup);
            scaling_json.push(o.finish());
            if threads == 4 {
                speedup_at_4 = Some(speedup);
            }
        }
        if let Some(s) = speedup_at_4 {
            println!(
                "run_batch speedup on 4 threads: {s:.2}x {}",
                if s >= 2.0 { "(>= 2x: PASS)" } else { "(< 2x)" }
            );
        }
        {
            let mut o = JsonObj::new();
            o.str_field("section", "run_batch_scaling")
                .u64_field("batch", batch_n as u64)
                .f64_field("sequential_images_per_s", seq_stats.per_second(batch_n))
                .f64_field("speedup_at_4_threads", speedup_at_4.unwrap_or(0.0))
                .raw_field("threads", &json_array(&scaling_json));
            sections.push(o.finish());
        }
        {
            let mut sim = Simulator::new(&program);
            let out = sim.run_batch_threads(&inputs, 4.min(hw))?;
            println!(
                "pipeline report: steady period {} cycles -> {:.0} img/s modeled \
                 (asserted == perfmodel)\n",
                out.pipeline.steady_period_cycles,
                out.modeled_images_per_s()
            );
        }

        // ---- 2. closed-loop serving on the sim backend ----------------
        let cfg = ServeConfig {
            workers: if smoke { 2 } else { 4 },
            max_batch: 8,
            queue_cap: 1024,
            ..ServeConfig::default()
        };
        let clients = if smoke { 2 } else { 4 };
        let per_client = if smoke { 8 } else { 64 };

        // request pool with precomputed refcompute references
        let pool: Vec<Vec<i8>> = (0..16)
            .map(|_| rng.i8_vec(net.input_len(), 31))
            .collect();
        let expected: Vec<Vec<i8>> = pool
            .iter()
            .map(|img| {
                forward(&net, &weights, &Tensor::new(net.input, img.clone()))
                    .map(|t| t.data)
            })
            .collect::<Result<_, _>>()?;
        let pool = Arc::new(pool);
        let expected = Arc::new(expected);

        println!(
            "closed-loop serve: {} workers, micro-batch {}, {} clients x {} requests",
            cfg.workers, cfg.max_batch, clients, per_client
        );
        let server = Arc::new(Server::start_sim(cfg, Arc::clone(&program))?);
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..clients {
            let server = Arc::clone(&server);
            let pool = Arc::clone(&pool);
            let expected = Arc::clone(&expected);
            handles.push(std::thread::spawn(move || -> anyhow::Result<LatencyStats> {
                let mut lat = LatencyStats::default();
                for i in 0..per_client {
                    let idx = (c * per_client + i) % pool.len();
                    let t = Instant::now();
                    let resp = server.infer(pool[idx].clone())?;
                    lat.record(t.elapsed());
                    anyhow::ensure!(
                        resp.logits == expected[idx],
                        "response for image {idx} diverged from refcompute"
                    );
                }
                Ok(lat)
            }));
        }
        let mut lat = LatencyStats::default();
        for h in handles {
            lat.merge(&h.join().expect("client thread")?);
        }
        let wall = t0.elapsed();
        let total = clients * per_client;
        println!(
            "served {total} requests in {:.2} s -> {:.1} img/s (all bit-exact vs refcompute)",
            wall.as_secs_f64(),
            domino::sim::stats::safe_rate(total as f64, wall.as_secs_f64())
        );
        println!("latency: {}", lat.summary());
        sections.push(section_json(
            "closed_loop_sim",
            total,
            wall.as_secs_f64(),
            &lat,
        ));
        println!(
            "server counters: served {}, rejected {}, failed {}",
            server.served(),
            server.rejected(),
            server.failed()
        );
        let counts = Arc::try_unwrap(server)
            .map_err(|_| anyhow::anyhow!("server still referenced"))?
            .shutdown()?;
        println!("per-worker served: {counts:?}\n");
    }

    let names: Vec<String> = model_list
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    anyhow::ensure!(
        names.len() >= 2,
        "--models needs >= 2 models for the multi-model leg (got {names:?})"
    );

    // ---- 3. multi-model closed loop with a mid-traffic hot-swap ----
    if !remote_only && !cluster_only {
    let registry = Arc::new(ModelRegistry::new());
    let mut models: Vec<Arc<ModelVersion>> = Vec::new();
    for raw in &names {
        let m = zoo::lookup(raw)?;
        models.push(registry.load(&m.name, &m, ArchConfig::default())?);
    }
    let cfg = ServeConfig {
        workers: if smoke { 2 } else { 4 },
        max_batch: 8,
        queue_cap: 4096,
        ..ServeConfig::default()
    };
    let clients = if smoke { 3 } else { 6 };
    let per_client = if smoke { 12 } else { 48 };
    println!(
        "multi-model closed loop: {} models [{}], {} workers, {} clients x {} requests, \
         hot-swap of {} mid-traffic",
        models.len(),
        models.iter().map(|m| m.name()).collect::<Vec<_>>().join(", "),
        cfg.workers,
        clients,
        per_client,
        models[0].name()
    );

    // per-model image pools; expected outputs per (model, version)
    let pools: Arc<Vec<Vec<Vec<i8>>>> = Arc::new(
        models
            .iter()
            .map(|mv| {
                (0..8)
                    .map(|_| rng.i8_vec(mv.input_len(), 31))
                    .collect::<Vec<_>>()
            })
            .collect(),
    );
    // expected refcompute outputs keyed by (model index, version)
    type ExpectedMap = HashMap<(usize, u64), Vec<Vec<i8>>>;
    let expected: Arc<Mutex<ExpectedMap>> = Arc::new(Mutex::new(HashMap::new()));
    for (mi, mv) in models.iter().enumerate() {
        expected
            .lock()
            .unwrap()
            .insert((mi, mv.version()), expected_for(mv, &pools[mi])?);
    }

    type Record = (usize, u64, usize, Vec<i8>); // (model idx, version, image idx, logits)
    let server = Arc::new(Server::start_multi(cfg, Arc::clone(&registry))?);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let server = Arc::clone(&server);
        let pools = Arc::clone(&pools);
        let model_names: Vec<String> =
            models.iter().map(|m| m.name().to_string()).collect();
        handles.push(std::thread::spawn(
            move || -> anyhow::Result<(LatencyStats, Vec<Record>)> {
                let mut lat = LatencyStats::default();
                let mut records = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    // every client cycles through every model
                    let mi = (c + i) % model_names.len();
                    let idx = i % pools[mi].len();
                    let t = Instant::now();
                    let resp = server.infer_on(&model_names[mi], pools[mi][idx].clone())?;
                    lat.record(t.elapsed());
                    let stamp = resp.model.expect("sim responses carry a stamp");
                    anyhow::ensure!(
                        &*stamp.name == model_names[mi].as_str(),
                        "request for {} answered by {} (routing bug)",
                        model_names[mi],
                        stamp.name
                    );
                    records.push((mi, stamp.version, idx, resp.logits));
                }
                Ok((lat, records))
            },
        ));
    }

    // Admin op while traffic flows: once a quarter of the requests are
    // served, hot-swap model 0 to fresh weights. In-flight requests on
    // v1 must drain; later requests pick up v2.
    let total = clients * per_client;
    let deadline = Instant::now() + std::time::Duration::from_secs(120);
    while server.served() < (total / 4) as u64 && Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_micros(200));
    }
    let swap_net = zoo::lookup(models[0].name())?;
    let v2 = registry.swap_seeded(
        models[0].name(),
        &swap_net,
        ArchConfig::default(),
        Some(0x5A_AB_5A),
    )?;
    expected
        .lock()
        .unwrap()
        .insert((0, v2.version()), expected_for(&v2, &pools[0])?);
    println!(
        "swapped {} v{} -> v{} at ~{} served",
        v2.name(),
        v2.version() - 1,
        v2.version(),
        server.served()
    );

    let mut lat = LatencyStats::default();
    let mut records: Vec<Record> = Vec::new();
    for h in handles {
        let (l, r) = h.join().expect("client thread")?;
        lat.merge(&l);
        records.extend(r);
    }
    let wall = t0.elapsed();

    // Deterministic post-swap coverage: the closed-loop clients may
    // race the swap, so drive the swapped model directly — these
    // requests are submitted strictly after `swap_seeded` returned and
    // MUST be served by v2, bit-exact under v2's weights.
    {
        let v2_expected = expected_for(&v2, &pools[0])?;
        for (idx, img) in pools[0].iter().enumerate().take(4) {
            let r = server.infer_on(v2.name(), img.clone())?;
            let stamp = r.model.expect("stamped");
            assert_eq!(
                stamp.version,
                v2.version(),
                "post-swap request served by the old version"
            );
            assert_eq!(
                r.logits, v2_expected[idx],
                "post-swap response diverged from the new weights"
            );
        }
    }

    // verify every response against the exact (model, version) that
    // served it
    let expected = expected.lock().unwrap();
    let mut by_version: HashMap<(usize, u64), usize> = HashMap::new();
    for (mi, version, idx, logits) in &records {
        let want = expected
            .get(&(*mi, *version))
            .unwrap_or_else(|| panic!("unexpected version {version} for model {mi}"));
        assert_eq!(
            logits, &want[*idx],
            "model {mi} v{version} image {idx} diverged from refcompute"
        );
        *by_version.entry((*mi, *version)).or_insert(0) += 1;
    }
    assert_eq!(records.len(), total, "every request must be answered");
    assert_eq!(server.failed(), 0, "no request may fail");
    assert_eq!(server.rejected(), 0, "no request may be rejected");
    println!(
        "served {total} mixed-model requests in {:.2} s -> {:.1} img/s \
         (all bit-exact vs refcompute per model version: PASS)",
        wall.as_secs_f64(),
        domino::sim::stats::safe_rate(total as f64, wall.as_secs_f64())
    );
    let mut split: Vec<_> = by_version.iter().collect();
    split.sort();
    for ((mi, version), count) in split {
        println!("  {} v{version}: {count} responses", models[*mi].name());
    }
    println!("latency: {}", lat.summary());
    sections.push(section_json(
        "multi_model_closed_loop",
        total,
        wall.as_secs_f64(),
        &lat,
    ));
    let counts = Arc::try_unwrap(server)
        .map_err(|_| anyhow::anyhow!("server still referenced"))?
        .shutdown()?;
    println!("per-worker served: {counts:?}\n");
    }

    // ---- 4. the same mixed-model load over the remote path (TCP) ----
    // A remote call routes through the identical Service::dispatch the
    // in-process path uses, so every guarantee above must hold
    // byte-for-byte across the wire: stamps, refcompute exactness,
    // drain on swap, per-model stats.
    if !multi_only && !cluster_only {
        let registry = Arc::new(ModelRegistry::new());
        let mut models: Vec<Arc<ModelVersion>> = Vec::new();
        for raw in &names {
            let m = zoo::lookup(raw)?;
            models.push(registry.load_seeded(&m.name, &m, ArchConfig::default(), Some(0xC0DE))?);
        }
        let cfg = ServeConfig {
            workers: if smoke { 2 } else { 4 },
            max_batch: 8,
            queue_cap: 4096,
            ..ServeConfig::default()
        };
        let server = Server::start_multi(cfg, Arc::clone(&registry))?;
        let service = Arc::new(Service::new(server, ArchConfig::default()));
        let net = NetServer::bind("127.0.0.1:0", Arc::clone(&service))?;
        let addr = net.local_addr().to_string();
        let clients = if smoke { 2 } else { 4 };
        let per_client = if smoke { 8 } else { 32 };
        println!(
            "remote closed loop over TCP {addr}: {} models [{}], {} clients x {} requests, \
             remote hot-swap of {}",
            models.len(),
            models.iter().map(|m| m.name()).collect::<Vec<_>>().join(", "),
            clients,
            per_client,
            models[0].name()
        );

        let pools: Arc<Vec<Vec<Vec<i8>>>> = Arc::new(
            models
                .iter()
                .map(|mv| {
                    (0..8)
                        .map(|_| rng.i8_vec(mv.input_len(), 31))
                        .collect::<Vec<_>>()
                })
                .collect(),
        );
        let mut expected: HashMap<(usize, u64), Vec<Vec<i8>>> = HashMap::new();
        for (mi, mv) in models.iter().enumerate() {
            expected.insert((mi, mv.version()), expected_for(mv, &pools[mi])?);
        }

        type Record = (usize, u64, usize, Vec<i8>); // (model idx, version, image idx, logits)
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..clients {
            let addr = addr.clone();
            let pools = Arc::clone(&pools);
            let model_names: Vec<String> =
                models.iter().map(|m| m.name().to_string()).collect();
            handles.push(std::thread::spawn(
                move || -> anyhow::Result<(LatencyStats, Vec<Record>)> {
                    let mut client = Client::connect(&addr)?;
                    client.set_read_timeout(Some(std::time::Duration::from_secs(120)))?;
                    let mut lat = LatencyStats::default();
                    let mut records = Vec::with_capacity(per_client);
                    for i in 0..per_client {
                        let mi = (c + i) % model_names.len();
                        let idx = i % pools[mi].len();
                        let t = Instant::now();
                        let reply =
                            client.infer(Some(model_names[mi].as_str()), pools[mi][idx].clone())?;
                        lat.record(t.elapsed());
                        let stamp = reply.model.expect("remote responses carry a stamp");
                        anyhow::ensure!(
                            &*stamp.name == model_names[mi].as_str(),
                            "request for {} answered by {} (routing bug over TCP)",
                            model_names[mi],
                            stamp.name
                        );
                        records.push((mi, stamp.version, idx, reply.logits));
                    }
                    Ok((lat, records))
                },
            ));
        }

        // remote admin op while traffic flows: hot-swap model 0
        // through a second client connection
        let total = clients * per_client;
        let deadline = Instant::now() + std::time::Duration::from_secs(120);
        while service.server().served() < (total / 4) as u64 && Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        let mut admin = Client::connect(&addr)?;
        admin.set_read_timeout(Some(std::time::Duration::from_secs(120)))?;
        let st = admin.swap(models[0].name(), Some(0x5A_5A))?;
        let v2 = registry.get(models[0].name()).expect("just swapped");
        anyhow::ensure!(v2.id() == st.id, "swap stamp does not match the registry");
        expected.insert((0, v2.version()), expected_for(&v2, &pools[0])?);
        println!(
            "remote-swapped {} -> v{} at ~{} served",
            st.name,
            st.version,
            service.server().served()
        );

        let mut lat = LatencyStats::default();
        let mut records: Vec<Record> = Vec::new();
        for h in handles {
            let (l, r) = h.join().expect("client thread")?;
            lat.merge(&l);
            records.extend(r);
        }
        let wall = t0.elapsed();

        // deterministic post-swap coverage through the remote path:
        // these are submitted strictly after the remote swap returned
        // and MUST be served by v2 with v2's weights
        {
            let v2_expected = expected_for(&v2, &pools[0])?;
            for (idx, img) in pools[0].iter().enumerate().take(4) {
                let r = admin.infer(Some(v2.name()), img.clone())?;
                assert_eq!(
                    r.model.expect("stamped").version,
                    v2.version(),
                    "post-swap remote request served by the old version"
                );
                assert_eq!(
                    r.logits, v2_expected[idx],
                    "post-swap remote response diverged from the new weights"
                );
            }
        }

        // every remote response verified against the exact
        // (model, version) that served it
        for (mi, version, idx, logits) in &records {
            let want = expected
                .get(&(*mi, *version))
                .unwrap_or_else(|| panic!("unexpected version {version} for model {mi}"));
            assert_eq!(
                logits, &want[*idx],
                "model {mi} v{version} image {idx} diverged from refcompute over TCP"
            );
        }
        assert_eq!(records.len(), total, "every remote request must be answered");

        // remote per-model stats: zero failures, queue drained
        let stats_reply = admin.stats()?;
        assert_eq!(stats_reply.failed, 0, "no remote request may fail");
        assert_eq!(stats_reply.rejected, 0, "no remote request may be rejected");
        println!(
            "remote stats: served {} across {} per-model entries",
            stats_reply.served,
            stats_reply.models.len()
        );
        for m in &stats_reply.models {
            anyhow::ensure!(m.queue_depth == 0, "queue must be drained");
            println!(
                "  {}: served {}, p50 {} us, p95 {} us, p99 {} us",
                m.model,
                m.served,
                m.p50_us.unwrap_or(0),
                m.p95_us.unwrap_or(0),
                m.p99_us.unwrap_or(0)
            );
        }

        // remote unload, then clean shutdown (drain + join everything)
        admin.unload(models[1].name())?;
        anyhow::ensure!(
            registry.get(models[1].name()).is_none(),
            "remote unload must mutate the registry"
        );
        drop(admin);
        net.shutdown()?;
        let service = Arc::try_unwrap(service)
            .map_err(|_| anyhow::anyhow!("service still referenced"))?;
        let counts = service.shutdown()?;
        println!(
            "served {total} remote requests in {:.2} s -> {:.1} img/s \
             (all bit-exact vs refcompute per model version over TCP: PASS)",
            wall.as_secs_f64(),
            domino::sim::stats::safe_rate(total as f64, wall.as_secs_f64())
        );
        println!("latency: {}", lat.summary());
        sections.push(section_json("remote_tcp", total, wall.as_secs_f64(), &lat));
        println!("per-worker served: {counts:?}");
    }

    // ---- 5. cluster: router over spawned backend processes ----------
    // A multi-process closed loop: two real `domino serve` child
    // processes behind an in-process Router, mixed-model traffic, one
    // backend SIGKILLed mid-run — zero client-visible drops allowed,
    // every answer bit-exact vs refcompute. Then the protocol-v2
    // pipelining gate on the surviving cluster's TCP endpoint: one
    // connection, window-8 submit/await vs one-in-flight calls at the
    // same request count, required >= 2x.
    if cluster_only || (!multi_only && !remote_only) {
        use domino::serve::api::{Dispatcher, Request, Response};
        use domino::serve::{ClusterConfig, Router};
        use std::sync::atomic::{AtomicUsize, Ordering};

        struct Children(Vec<std::process::Child>);
        impl Drop for Children {
            fn drop(&mut self) {
                for c in &mut self.0 {
                    let _ = c.kill();
                    let _ = c.wait();
                }
            }
        }

        fn spawn_backend(workers: usize) -> anyhow::Result<(std::process::Child, String)> {
            use std::io::BufRead;
            let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_domino"))
                .args([
                    "serve",
                    "--backend",
                    "sim",
                    "--models",
                    "",
                    "--workers",
                    &workers.to_string(),
                    "--listen",
                    "127.0.0.1:0",
                ])
                .stdout(std::process::Stdio::piped())
                .stderr(std::process::Stdio::inherit())
                .spawn()?;
            let stdout = child.stdout.take().expect("stdout piped");
            let mut reader = std::io::BufReader::new(stdout);
            let mut line = String::new();
            let addr = loop {
                line.clear();
                anyhow::ensure!(
                    reader.read_line(&mut line)? > 0,
                    "backend exited before printing its listen address"
                );
                if let Some(rest) = line.strip_prefix("listening on ") {
                    break rest
                        .split_whitespace()
                        .next()
                        .expect("address token")
                        .to_string();
                }
            };
            // drain (and keep open) the child's stdout for its lifetime
            std::thread::spawn(move || {
                let mut sink = String::new();
                while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
                    sink.clear();
                }
            });
            Ok((child, addr))
        }

        // 4 workers even in smoke: the pipelining gate needs real
        // concurrency behind the window to show its speedup
        let backend_workers = 4;
        let (c1, a1) = spawn_backend(backend_workers)?;
        let (c2, a2) = spawn_backend(backend_workers)?;
        let mut children = Children(vec![c1, c2]);
        println!("cluster: spawned backends {a1} + {a2} ({backend_workers} workers each)");

        let router = Arc::new(Router::new(
            vec![a1, a2],
            ClusterConfig {
                replication: 2,
                ..ClusterConfig::default()
            },
        )?);

        // two models, seeded loads through the router; local reference
        // versions with identical (network, seed) are the oracle
        let cluster_names: Vec<String> = names.iter().take(2).cloned().collect();
        let local_reg = ModelRegistry::new();
        let mut refs: Vec<Arc<ModelVersion>> = Vec::new();
        for (i, m) in cluster_names.iter().enumerate() {
            let seed = 0xC1A0 + i as u64;
            match router.dispatch(Request::LoadSeeded {
                model: m.clone(),
                seed,
                mapping: None,
            }) {
                Response::Loaded(_) => {}
                other => anyhow::bail!("cluster load {m}: {other:?}"),
            }
            let net = zoo::lookup(m)?;
            refs.push(local_reg.load_seeded(
                &net.name,
                &net,
                ArchConfig::default(),
                Some(seed),
            )?);
        }
        let pools: Arc<Vec<Vec<Vec<i8>>>> = Arc::new(
            refs.iter()
                .map(|mv| {
                    (0..8)
                        .map(|_| rng.i8_vec(mv.input_len(), 31))
                        .collect::<Vec<_>>()
                })
                .collect(),
        );
        let expected: Arc<Vec<Vec<Vec<i8>>>> = Arc::new(
            refs.iter()
                .zip(pools.iter())
                .map(|(mv, pool)| expected_for(mv, pool))
                .collect::<anyhow::Result<_>>()?,
        );

        let clients = if smoke { 3 } else { 4 };
        let per_client = if smoke { 10 } else { 40 };
        let total = clients * per_client;
        let done = Arc::new(AtomicUsize::new(0));
        println!(
            "cluster closed loop: {} clients x {} mixed-model requests, \
             one backend killed at ~25%",
            clients, per_client
        );
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..clients {
            let router = Arc::clone(&router);
            let pools = Arc::clone(&pools);
            let expected = Arc::clone(&expected);
            let done = Arc::clone(&done);
            let model_names = cluster_names.clone();
            handles.push(std::thread::spawn(move || -> anyhow::Result<LatencyStats> {
                let mut lat = LatencyStats::default();
                for i in 0..per_client {
                    let mi = (c + i) % model_names.len();
                    let idx = i % pools[mi].len();
                    let t = Instant::now();
                    let resp = router.dispatch(Request::Infer {
                        model: Some(model_names[mi].clone()),
                        image: pools[mi][idx].clone(),
                    });
                    lat.record(t.elapsed());
                    match resp {
                        Response::Infer(r) => {
                            anyhow::ensure!(
                                r.logits == expected[mi][idx],
                                "cluster response for {} image {idx} diverged",
                                model_names[mi]
                            );
                            let stamp =
                                r.model.ok_or_else(|| anyhow::anyhow!("missing stamp"))?;
                            anyhow::ensure!(
                                &*stamp.name == model_names[mi].as_str(),
                                "request for {} answered by {}",
                                model_names[mi],
                                stamp.name
                            );
                        }
                        other => anyhow::bail!("request dropped or failed: {other:?}"),
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                }
                Ok(lat)
            }));
        }

        // SIGKILL one backend mid-run: in-flight calls to it fail over
        // to the replica; nothing is allowed to surface to a client
        let deadline = Instant::now() + std::time::Duration::from_secs(120);
        while done.load(Ordering::SeqCst) < total / 4 && Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        let killed_at = done.load(Ordering::SeqCst);
        children.0[0].kill()?;
        children.0[0].wait()?;
        println!("killed backend #0 at ~{killed_at} served");

        let mut lat = LatencyStats::default();
        for h in handles {
            lat.merge(&h.join().expect("cluster client thread")?);
        }
        let wall = t0.elapsed();
        // a failed routed call marks the backend dead; if traffic
        // finished before the kill landed, one probe pass settles it
        router.health_pass();
        let st = router.status();
        anyhow::ensure!(
            st.backends.iter().any(|b| !b.alive),
            "the killed backend must be marked dead"
        );
        println!(
            "cluster served {total}/{total} requests in {:.2} s -> {:.1} img/s \
             (0 dropped, all bit-exact across the kill: PASS)",
            wall.as_secs_f64(),
            domino::sim::stats::safe_rate(total as f64, wall.as_secs_f64())
        );
        println!("latency: {}", lat.summary());

        // ---- protocol-v2 pipelining gate on the router endpoint ----
        let net = NetServer::bind("127.0.0.1:0", Arc::clone(&router))?;
        let addr = net.local_addr().to_string();
        let gate_n = if smoke { 24 } else { 96 };
        let gate_model = cluster_names[0].as_str();
        let gate_pool = &pools[0];
        let gate_expected = &expected[0];

        // one-in-flight: request, wait, repeat
        let mut serial = Client::connect(&addr)?;
        serial.set_read_timeout(Some(std::time::Duration::from_secs(120)))?;
        let t0 = Instant::now();
        for i in 0..gate_n {
            let idx = i % gate_pool.len();
            let r = serial.infer(Some(gate_model), gate_pool[idx].clone())?;
            anyhow::ensure!(r.logits == gate_expected[idx], "serial response diverged");
        }
        let serial_secs = t0.elapsed().as_secs_f64();
        let serial_rate = domino::sim::stats::safe_rate(gate_n as f64, serial_secs);

        // pipelined: same connection count (one), window of 8 in flight
        let mut piped = Client::connect(&addr)?;
        piped.set_read_timeout(Some(std::time::Duration::from_secs(120)))?;
        let t0 = Instant::now();
        let mut inflight: std::collections::VecDeque<(u64, usize)> =
            std::collections::VecDeque::new();
        for i in 0..gate_n {
            let idx = i % gate_pool.len();
            if inflight.len() >= 8 {
                let (rid, idx) = inflight.pop_front().expect("window non-empty");
                let r = piped.await_infer(rid)?;
                anyhow::ensure!(r.logits == gate_expected[idx], "pipelined response diverged");
            }
            let rid = piped.infer_submit(Some(gate_model), gate_pool[idx].clone())?;
            inflight.push_back((rid, idx));
        }
        while let Some((rid, idx)) = inflight.pop_front() {
            let r = piped.await_infer(rid)?;
            anyhow::ensure!(r.logits == gate_expected[idx], "pipelined response diverged");
        }
        let piped_secs = t0.elapsed().as_secs_f64();
        let piped_rate = domino::sim::stats::safe_rate(gate_n as f64, piped_secs);
        let speedup = if serial_secs > 0.0 { serial_secs / piped_secs.max(1e-9) } else { 0.0 };
        println!(
            "pipelining gate on one connection: serial {serial_rate:.1} img/s, \
             window-8 {piped_rate:.1} img/s -> {speedup:.2}x {}",
            if speedup >= 2.0 { "(>= 2x: PASS)" } else { "(< 2x: FAIL)" }
        );

        {
            let mut o = JsonObj::new();
            o.str_field("section", "cluster")
                .u64_field("requests", total as u64)
                .f64_field(
                    "images_per_s",
                    domino::sim::stats::safe_rate(total as f64, wall.as_secs_f64()),
                )
                .u64_field("p50_us", lat.percentile(50.0).unwrap_or(0))
                .u64_field("p95_us", lat.percentile(95.0).unwrap_or(0))
                .u64_field("p99_us", lat.percentile(99.0).unwrap_or(0))
                .u64_field("backend_killed_at", killed_at as u64)
                .u64_field("dropped", 0)
                .f64_field("serial_images_per_s", serial_rate)
                .f64_field("pipelined_images_per_s", piped_rate)
                .f64_field("pipelined_speedup", speedup);
            sections.push(o.finish());
        }

        drop(serial);
        drop(piped);
        net.shutdown()?;
        drop(router);
        drop(children);
        if speedup < 2.0 {
            // fail AFTER the json report is written, so the artifact
            // still records the regressed number
            gate_failures.push(format!(
                "pipelined throughput {speedup:.2}x is below the 2x acceptance gate"
            ));
        }
        println!();
    }

    // ---- 6. hostile-reality scenarios (see serve::traffic) ----------
    // Overload past queue_cap (typed rejections only, zero drops), a
    // bursty open-loop run, an admin+data storm, a slow-loris TCP
    // client, and the SLO-conditioned load search. The suite enforces
    // its own invariants (any violation is an Err), and its report
    // lands in BENCH_serve.json as the `scenarios` section so reject
    // counts and the sustained-rate-at-SLO trend run over run.
    let scenarios = if !multi_only && !remote_only && !cluster_only {
        let report = domino::serve::traffic::scenario_suite(&names, smoke, 0xBEEF)?;
        println!(
            "\nscenarios: overload {}/{} rejected typed (0 dropped, 0 failed); \
             burst p99 {} us; storm {} swaps under flood; loris served {} well-behaved; \
             slo max rate {}/s at p99 {} us (bound {} us)",
            report.overload.rejected,
            report.overload.submitted,
            report.burst.p99_us.unwrap_or(0),
            report.storm.swaps_ok,
            report.loris.map(|l| l.wellbehaved_ok).unwrap_or(0),
            report.slo.max_rate_per_s,
            report.slo.p99_at_max_us,
            report.slo.slo_p99_us
        );
        Some(domino::serve::wire::encode(&report.to_json()))
    } else {
        None
    };

    if let Some(path) = json_path {
        let mut doc = JsonObj::new();
        doc.str_field("bench", "serve_sim_throughput")
            .str_field("mode", if smoke { "smoke" } else { "full" })
            .str_field("models", &model_list)
            .raw_field("sections", &json_array(&sections));
        if let Some(s) = &scenarios {
            doc.raw_field("scenarios", s);
        }
        write_json(&path, &doc.finish())?;
    }
    anyhow::ensure!(
        gate_failures.is_empty(),
        "acceptance gate(s) failed: {}",
        gate_failures.join("; ")
    );
    Ok(())
}
