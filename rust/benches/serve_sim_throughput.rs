//! Serving throughput on the cycle-simulator backend, end to end:
//!
//! 1. `Simulator::run_batch` scaling — an 8-image batch, 1 vs N
//!    threads, bit-exactness asserted against sequential `run_image`
//!    and the wall-clock speedup printed (the PR's ≥2x-on-4-threads
//!    acceptance gate);
//! 2. a closed-loop load test of the `serve` bounded-queue /
//!    micro-batch loop with the [`Server::start_sim`] backend —
//!    concurrent clients, p50/p95/p99 latency, served images/s, and a
//!    bit-exact cross-check of every response against
//!    `model::refcompute`.
//!
//!     cargo bench --bench serve_sim_throughput            # full run
//!     cargo bench --bench serve_sim_throughput -- --smoke # CI-sized

use std::sync::Arc;
use std::time::Instant;

use domino::benchutil::{stats, time_n};
use domino::coordinator::ArchConfig;
use domino::model::refcompute::{forward, Tensor};
use domino::model::zoo;
use domino::serve::{sim_program, LatencyStats, ServeConfig, Server};
use domino::sim::Simulator;
use domino::testutil::Rng;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "serve_sim_throughput ({})\n",
        if smoke { "smoke" } else { "full" }
    );
    let net = zoo::tiny_cnn();
    let (program, weights) = sim_program(&net, ArchConfig::default())?;

    // ---- 1. run_batch scaling ------------------------------------
    let batch_n = if smoke { 4 } else { 8 };
    let iters = if smoke { 1 } else { 3 };
    let mut rng = Rng::new(0xBEEF);
    let inputs: Vec<Vec<i8>> = (0..batch_n)
        .map(|_| rng.i8_vec(net.input_len(), 31))
        .collect();

    // sequential reference (also the exactness oracle)
    let mut seq_sim = Simulator::new(&program);
    let seq_scores: Vec<Vec<i8>> = inputs
        .iter()
        .map(|x| seq_sim.run_image(x).map(|o| o.scores))
        .collect::<anyhow::Result<_>>()?;
    let seq_stats = stats(time_n(iters, || {
        let mut sim = Simulator::new(&program);
        for x in &inputs {
            std::hint::black_box(sim.run_image(x).unwrap());
        }
    }));
    println!(
        "{batch_n}-image batch, sequential run_image:   {:>10.3?} ({:.1} img/s)",
        seq_stats.median,
        seq_stats.per_second(batch_n)
    );

    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut thread_counts = vec![1usize, 2, 4];
    if hw > 4 {
        thread_counts.push(hw);
    }
    let mut speedup_at_4 = None;
    for threads in thread_counts {
        // exactness first: every batched output must equal sequential
        let mut sim = Simulator::new(&program);
        let out = sim.run_batch_threads(&inputs, threads)?;
        for (i, (o, want)) in out.outputs.iter().zip(&seq_scores).enumerate() {
            assert_eq!(o.scores, *want, "image {i} diverged at {threads} threads");
        }
        let st = stats(time_n(iters, || {
            let mut sim = Simulator::new(&program);
            std::hint::black_box(sim.run_batch_threads(&inputs, threads).unwrap());
        }));
        let speedup = st.speedup_over(&seq_stats);
        println!(
            "{batch_n}-image batch, run_batch x{threads:>2} threads: {:>10.3?} \
             ({:.1} img/s, {speedup:.2}x vs sequential, bit-exact)",
            st.median,
            st.per_second(batch_n)
        );
        if threads == 4 {
            speedup_at_4 = Some(speedup);
        }
    }
    if let Some(s) = speedup_at_4 {
        println!(
            "run_batch speedup on 4 threads: {s:.2}x {}",
            if s >= 2.0 { "(>= 2x: PASS)" } else { "(< 2x)" }
        );
    }
    {
        let mut sim = Simulator::new(&program);
        let out = sim.run_batch_threads(&inputs, 4.min(hw))?;
        println!(
            "pipeline report: steady period {} cycles -> {:.0} img/s modeled \
             (asserted == perfmodel)\n",
            out.pipeline.steady_period_cycles,
            out.modeled_images_per_s()
        );
    }

    // ---- 2. closed-loop serving on the sim backend ----------------
    let cfg = ServeConfig {
        workers: if smoke { 2 } else { 4 },
        max_batch: 8,
        queue_cap: 1024,
    };
    let clients = if smoke { 2 } else { 4 };
    let per_client = if smoke { 8 } else { 64 };

    // request pool with precomputed refcompute references
    let pool: Vec<Vec<i8>> = (0..16)
        .map(|_| rng.i8_vec(net.input_len(), 31))
        .collect();
    let expected: Vec<Vec<i8>> = pool
        .iter()
        .map(|img| {
            forward(&net, &weights, &Tensor::new(net.input, img.clone()))
                .map(|t| t.data)
        })
        .collect::<Result<_, _>>()?;
    let pool = Arc::new(pool);
    let expected = Arc::new(expected);

    println!(
        "closed-loop serve: {} workers, micro-batch {}, {} clients x {} requests",
        cfg.workers, cfg.max_batch, clients, per_client
    );
    let server = Arc::new(Server::start_sim(cfg, Arc::clone(&program))?);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let server = Arc::clone(&server);
        let pool = Arc::clone(&pool);
        let expected = Arc::clone(&expected);
        handles.push(std::thread::spawn(move || -> anyhow::Result<LatencyStats> {
            let mut lat = LatencyStats::default();
            for i in 0..per_client {
                let idx = (c * per_client + i) % pool.len();
                let t = Instant::now();
                let resp = server.infer(pool[idx].clone())?;
                lat.record(t.elapsed());
                anyhow::ensure!(
                    resp.logits == expected[idx],
                    "response for image {idx} diverged from refcompute"
                );
            }
            Ok(lat)
        }));
    }
    let mut lat = LatencyStats::default();
    for h in handles {
        lat.merge(&h.join().expect("client thread")?);
    }
    let wall = t0.elapsed();
    let total = clients * per_client;
    println!(
        "served {total} requests in {:.2} s -> {:.1} img/s (all bit-exact vs refcompute)",
        wall.as_secs_f64(),
        domino::sim::stats::safe_rate(total as f64, wall.as_secs_f64())
    );
    println!("latency: {}", lat.summary());
    println!(
        "server counters: served {}, rejected {}, failed {}",
        server.served(),
        server.rejected(),
        server.failed()
    );
    let counts = Arc::try_unwrap(server)
        .map_err(|_| anyhow::anyhow!("server still referenced"))?
        .shutdown()?;
    println!("per-worker served: {counts:?}");
    Ok(())
}
