//! Ablation: chip-aligned chain placement. A psum chain straddling a
//! chip boundary pays 0.55 pJ/b transceiver energy per hop instead of
//! 0.05 pJ/b mesh energy; aligning chains to chip boundaries trades a
//! few pad tiles for that energy.

use domino::coordinator::{ArchConfig, Compiler};
use domino::energy::{energy_of, CimModel};
use domino::model::zoo;

fn main() {
    println!("chip-aligned chain placement (multi-chip workloads)\n");
    println!(
        "{:<18} {:>18} {:>18} {:>14} {:>12}",
        "model", "interchip uJ base", "interchip aligned", "tiles (pad)", "energy x"
    );
    let cim = CimModel::generic_sram();
    for (net, _) in zoo::table4_workloads() {
        let base = Compiler::default().compile_analysis(&net).unwrap();
        let mut arch = ArchConfig::default();
        arch.chip_aligned_chains = true;
        let aligned = Compiler::new(arch).compile_analysis(&net).unwrap();
        let eb = energy_of(
            &domino::perfmodel::estimate(&base).unwrap().counters,
            &cim,
        );
        let ea = energy_of(
            &domino::perfmodel::estimate(&aligned).unwrap().counters,
            &cim,
        );
        println!(
            "{:<18} {:>17.3} {:>18.3} {:>8} (+{:>3}) {:>11.3}x",
            net.name,
            1e6 * eb.interchip,
            1e6 * ea.interchip,
            aligned.total_tiles,
            aligned.total_tiles as isize - base.total_tiles as isize,
            eb.total() / ea.total(),
        );
        assert!(ea.interchip <= eb.interchip, "{}", net.name);
    }
}
