//! Experiment F2 — paper Fig. 2: FC-layer dataflow. A blocked
//! matrix-vector multiplication is mapped to a ⌈Cin/Nc⌉ x ⌈Cout/Nm⌉
//! tile grid; partial sums accumulate while moving down each column;
//! the bottom tile emits one output slice; concatenating columns gives
//! the BMM result.

use domino::benchutil::bench;
use domino::coordinator::program::StageKind;
use domino::coordinator::{ArchConfig, Compiler};
use domino::model::refcompute::{forward, Tensor, Weights};
use domino::model::{NetworkBuilder, TensorShape};
use domino::sim::Simulator;
use domino::testutil::Rng;

fn main() {
    // the figure's geometry: a 4-column, 2-row tile grid
    // (Cin = 2 Nc, Cout = 4 Nm at Nc = Nm = 256)
    let net = NetworkBuilder::new("fig2", TensorShape::new(512, 1, 1))
        .fc_logits(1024)
        .build();
    let program = Compiler::default().compile(&net).unwrap();
    let StageKind::Fc(f) = &program.stages[0].kind else {
        panic!("fc stage")
    };
    println!(
        "FC 512 -> 1024 maps to {} columns x {} row-blocks = {} tiles\n",
        f.cblocks,
        f.rblocks,
        program.total_tiles
    );
    for col in &f.columns {
        let path: Vec<String> = col
            .tiles
            .iter()
            .map(|t| format!("({},{})", t.coord.row, t.coord.col))
            .collect();
        println!(
            "column {} (outputs {}..{}): psum chain {}",
            col.cblock,
            col.c_lo,
            col.c_hi,
            path.join(" -> ")
        );
    }

    // functional check + bench
    let compiler = Compiler::new(ArchConfig::default());
    let weights = Weights::random(&net, compiler.weight_seed).unwrap();
    let program = compiler.compile_with_weights(&net, &weights).unwrap();
    let mut rng = Rng::new(2);
    let input = Tensor::new(net.input, rng.i8_vec(512, 31));
    let mut sim = Simulator::new(&program);
    let got = sim.run_image(&input.data).unwrap();
    let want = forward(&net, &weights, &input).unwrap();
    assert_eq!(got.scores, want.data);
    println!("\nBMM result matches the int8 reference (concatenated column slices)");

    println!();
    bench("fig2: FC 512x1024 cycle sim", 10, || {
        let mut sim = Simulator::new(&program);
        std::hint::black_box(sim.run_image(&input.data).unwrap());
    });
}
