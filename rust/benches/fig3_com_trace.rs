//! Experiment F3 — paper Fig. 3: CONV mapping + the COM timing/location
//! trace (partial-sums moving through registers, group-sums waiting in
//! ROFM buffers).

use domino::benchutil::bench;
use domino::coordinator::Compiler;
use domino::model::{NetworkBuilder, TensorShape};
use domino::sim::trace::trace_stage;

fn main() {
    let net = NetworkBuilder::new("fig3", TensorShape::new(2, 5, 5))
        .conv(3, 3, 1, 1)
        .build();
    let program = Compiler::default().compile(&net).unwrap();
    let tr = trace_stage(&program, 0, 7).unwrap();
    print!("{}", tr.render(0, 30));
    println!(
        "\nevents: {} psum moves, {} group-sums queued, {} popped, {} outputs",
        tr.count("U"),
        tr.count("G+"),
        tr.count("G-"),
        tr.count("Y")
    );
    // rendered cells dedup per (tile, slot); both buffer directions
    // must appear at the kernel-row heads
    assert!(tr.count("G+") > 0 && tr.count("G-") > 0);

    println!();
    bench("fig3: trace capture (flight recorder on)", 10, || {
        std::hint::black_box(trace_stage(&program, 0, 7).unwrap());
    });
}
