//! Reproduce paper Fig. 3(b): the timing and location of partial-sums
//! (registers) and group-sums (ROFM buffers) as they are computed on
//! the move through a K=3 convolution chain.
//!
//!     cargo run --release --example dataflow_trace

use domino::coordinator::Compiler;
use domino::model::{NetworkBuilder, TensorShape};
use domino::sim::trace::trace_stage;

fn main() -> anyhow::Result<()> {
    // the paper's illustration geometry: K=3 => a 9-tile chain
    let net = NetworkBuilder::new("fig3", TensorShape::new(2, 5, 5))
        .conv(3, 3, 1, 1)
        .build();
    let program = Compiler::default().compile(&net)?;
    let tr = trace_stage(&program, 0, 7)?;
    print!("{}", tr.render(0, 30));
    println!(
        "\n{} partial-sum moves, {} group-sums queued, {} popped, {} outputs",
        tr.count("U"),
        tr.count("G+"),
        tr.count("G-"),
        tr.count("Y")
    );
    println!("\nNote the paper's structure: tiles 3 and 6 (kernel-row heads)");
    println!("queue group-sums and pop them one row-period later; outputs");
    println!("leave only the last tile (8) after the M-type activation.");
    Ok(())
}
