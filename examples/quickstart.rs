//! Quickstart: compile a small CNN onto the Domino mesh, run one
//! cycle-accurate inference, and price it with the paper's Table III
//! energy model.
//!
//!     cargo run --release --example quickstart

use domino::coordinator::Compiler;
use domino::energy::{energy_of, CimModel};
use domino::model::zoo;
use domino::sim::Simulator;
use domino::testutil::Rng;

fn main() -> anyhow::Result<()> {
    // 1. a network from the zoo (every Table IV model is available)
    let net = zoo::tiny_cnn();
    println!("network: {} ({} layers)", net.name, net.layers.len());

    // 2. the Domino compiler: tile allocation + per-tile periodic
    //    instruction schedules (the paper's distributed control)
    let program = Compiler::default().compile(&net)?;
    println!(
        "mapped to {} tiles on {} chip(s); schedules fit the 128-entry table: {}",
        program.total_tiles,
        program.chips,
        program.schedules_fit_hardware()
    );

    // 3. cycle-accurate simulation of one image
    let mut sim = Simulator::new(&program);
    let mut rng = Rng::new(42);
    let out = sim.run_image(&rng.i8_vec(net.input_len(), 31))?;
    println!(
        "latency: {} cycles = {:.1} us at 10 MHz",
        out.latency_cycles,
        1e6 * out.latency_cycles as f64 / domino::consts::STEP_HZ
    );
    println!("scores: {:?}", out.scores);

    // 4. energy from the architectural event counters (Table III)
    let e = energy_of(sim.stats(), &CimModel::generic_sram());
    println!(
        "energy/image: {:.3} uJ (CIM {:.1}%, on-chip data {:.1}%, off-chip {:.2}%)",
        1e6 * e.total(),
        100.0 * e.cim / e.total(),
        100.0 * e.onchip_data() / e.total(),
        100.0 * e.offchip_data() / e.total()
    );

    // 5. the analytic model (used for the full Table IV networks)
    let est = domino::perfmodel::estimate(&program)?;
    println!(
        "pipelined: {:.0} images/s (period {} cycles)",
        est.images_per_s(),
        est.period_cycles
    );
    Ok(())
}
