//! END-TO-END driver: the full three-layer stack on a real (small)
//! workload.
//!
//! 1. `make artifacts` (build time, once): JAX trains TinyCNN in fp32
//!    on a synthetic 10-class dataset, calibrates + quantizes to int8,
//!    and AOT-lowers the quantized forward — built from the L1 Pallas
//!    kernels — to HLO text.
//! 2. This binary (run time, no Python): loads the trained HLO through
//!    the PJRT runtime, loads the exported weights + held-out test set,
//!    and serves the whole test set batch by batch, measuring wall
//!    latency/throughput of the compiled artifact.
//! 3. The same images run through the cycle-accurate Domino simulator:
//!    outputs must match the HLO **bit-for-bit** (the COM dataflow is
//!    functionally exact), while the simulator additionally reports
//!    modeled cycles and Table III energy.
//!
//!     make artifacts && cargo run --release --example e2e_inference

use std::time::Instant;

use domino::coordinator::Compiler;
use domino::energy::{energy_of, CimModel};
use domino::eval::accuracy::{tiny_cnn_with_shifts, TestSet, TrainedWeights};
use domino::runtime::golden::TrainedTiny;
use domino::runtime::{artifact, artifacts_dir, Runtime};
use domino::sim::Simulator;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    if !dir.join(artifact::TINY_TRAINED).exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }

    // ---- load the deployable artifact (AOT HLO, weights baked in)
    let rt = Runtime::cpu()?;
    let hlo = TrainedTiny::load(&rt)?;
    let tw = TrainedWeights::load(&dir.join(artifact::WEIGHTS_BIN))?;
    let ts = TestSet::load(&dir.join(artifact::TESTSET_BIN))?;
    println!(
        "loaded {} on PJRT/{}; test set: {} images",
        artifact::TINY_TRAINED,
        rt.platform(),
        ts.images.len()
    );

    // ---- serve the test set through the compiled HLO
    let t0 = Instant::now();
    let mut correct = 0usize;
    let mut hlo_outputs = Vec::with_capacity(ts.images.len());
    for (img, &label) in ts.images.iter().zip(&ts.labels) {
        let logits = hlo.run(img)?;
        if argmax(&logits) == label as usize {
            correct += 1;
        }
        hlo_outputs.push(logits);
    }
    let wall = t0.elapsed();
    let acc = correct as f64 / ts.images.len() as f64;
    println!(
        "\nHLO serving: {} images in {:.1} ms ({:.0} img/s wall), accuracy {:.4}",
        ts.images.len(),
        1e3 * wall.as_secs_f64(),
        ts.images.len() as f64 / wall.as_secs_f64(),
        acc
    );

    // ---- the same network through the cycle-accurate simulator
    let net = tiny_cnn_with_shifts(tw.shifts());
    let program = Compiler::default().compile_with_weights(&net, &tw.as_weights())?;
    println!(
        "\nDomino mapping: {} tiles, {} chip(s)",
        program.total_tiles, program.chips
    );
    let mut sim = Simulator::new(&program);
    let n_sim = 16.min(ts.images.len());
    let mut latency = 0u64;
    for i in 0..n_sim {
        let out = sim.run_image(&ts.images[i])?;
        assert_eq!(
            out.scores, hlo_outputs[i],
            "image {i}: simulator != AOT HLO (datapath bug)"
        );
        latency = out.latency_cycles;
    }
    println!(
        "cycle sim: {n_sim} images, all outputs == HLO bit-exactly; \
         latency {} cycles ({:.1} us @10 MHz)",
        latency,
        1e6 * latency as f64 / domino::consts::STEP_HZ
    );

    let est = domino::perfmodel::estimate(&program)?;
    let e = energy_of(&est.counters, &CimModel::generic_sram());
    println!(
        "modeled: {:.0} img/s pipelined, {:.3} uJ/image \
         (CIM {:.1}%, on-chip {:.1}%, off-chip {:.2}%)",
        est.images_per_s(),
        1e6 * e.total(),
        100.0 * e.cim / e.total(),
        100.0 * e.onchip_data() / e.total(),
        100.0 * e.offchip_data() / e.total()
    );

    // ---- the accuracy experiment record (paper Table IV accuracy row)
    let rep = domino::eval::accuracy::run(&dir, 0)?;
    print!("\n{}", domino::eval::accuracy::render(&rep));
    Ok(())
}

fn argmax(v: &[i8]) -> usize {
    v.iter()
        .enumerate()
        .max_by_key(|&(i, &x)| (x, std::cmp::Reverse(i)))
        .map(|(i, _)| i)
        .unwrap_or(0)
}
