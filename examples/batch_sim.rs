//! Batched simulation: run a batch of images through the cycle engine
//! data-parallel across threads, verify bit-exactness against the
//! sequential path, and read the pipelined steady-state report that the
//! paper's Table IV throughput numbers are built on.
//!
//!     cargo run --release --example batch_sim

use domino::coordinator::Compiler;
use domino::model::zoo;
use domino::sim::Simulator;
use domino::testutil::Rng;

fn main() -> anyhow::Result<()> {
    let net = zoo::tiny_cnn();
    let program = Compiler::default().compile(&net)?;
    println!(
        "network: {} mapped to {} tiles on {} chip(s)",
        net.name, program.total_tiles, program.chips
    );

    // a batch of images
    let mut rng = Rng::new(42);
    let inputs: Vec<Vec<i8>> = (0..8)
        .map(|_| rng.i8_vec(net.input_len(), 31))
        .collect();

    // 1. sequential reference: back-to-back run_image on one engine
    //    (per-tile state is built once and reset between images)
    let mut seq = Simulator::new(&program);
    let t0 = std::time::Instant::now();
    let seq_outs: Vec<_> = inputs
        .iter()
        .map(|x| seq.run_image(x))
        .collect::<Result<_, _>>()?;
    let t_seq = t0.elapsed();

    // 2. the batched path: images data-parallel across threads,
    //    per-thread counters merged deterministically
    let mut batched = Simulator::new(&program);
    let batch = batched.run_batch(&inputs)?;
    println!(
        "batch of {} on {} thread(s): {:.1} ms vs {:.1} ms sequential",
        batch.outputs.len(),
        batch.threads,
        1e3 * batch.wall.as_secs_f64(),
        1e3 * t_seq.as_secs_f64()
    );

    // 3. bit-exactness: same scores, same merged counters
    for (b, s) in batch.outputs.iter().zip(&seq_outs) {
        assert_eq!(b.scores, s.scores);
    }
    assert_eq!(batched.stats(), seq.stats());
    println!("outputs and merged counters bit-exact with the sequential path");

    // 4. the pipelined steady-state report (asserted against the
    //    analytic perfmodel inside run_batch)
    println!(
        "pipelined: first-image latency {:.1} us, steady period {} cycles \
         -> {:.0} img/s modeled at 10 MHz",
        1e6 * batch.pipeline.first_latency_cycles as f64 / domino::consts::STEP_HZ,
        batch.pipeline.steady_period_cycles,
        batch.pipeline.images_per_s
    );
    for s in &batch.pipeline.stages {
        println!(
            "  {:<12} {:>6} slots/img, lead {:>3}, utilization {:>5.1}%",
            s.name,
            s.slots,
            s.lead_slots,
            100.0 * s.utilization
        );
    }
    Ok(())
}
