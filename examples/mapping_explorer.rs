//! Mapping explorer: how crossbar size, chip budget and pooling scheme
//! change tiles / chips / period / throughput for the Table IV models.
//!
//!     cargo run --release --example mapping_explorer

use domino::coordinator::{ArchConfig, Compiler, PoolingScheme};
use domino::model::zoo;

fn main() -> anyhow::Result<()> {
    println!("== crossbar size sweep (block reuse, minimum mapping) ==");
    println!(
        "{:<18} {:>6} {:>8} {:>6} {:>12} {:>10}",
        "model", "Nc=Nm", "tiles", "chips", "period cyc", "img/s"
    );
    for (net, _) in zoo::table4_workloads() {
        for n in [64usize, 128, 256, 512] {
            let mut arch = ArchConfig::default();
            arch.n_c = n;
            arch.n_m = n;
            let program = Compiler::new(arch).compile_analysis(&net)?;
            let est = domino::perfmodel::estimate(&program)?;
            println!(
                "{:<18} {:>6} {:>8} {:>6} {:>12} {:>10.0}",
                net.name,
                n,
                program.total_tiles,
                program.chips,
                est.period_cycles,
                est.images_per_s()
            );
        }
        println!();
    }

    println!("== chip-budget sweep (duplication water-filling) ==");
    println!(
        "{:<18} {:>6} {:>8} {:>12} {:>10}",
        "model", "chips", "tiles", "period cyc", "img/s"
    );
    let net = zoo::vgg11_cifar();
    for chips in [1usize, 2, 3, 5, 8, 12] {
        let program = Compiler::new(ArchConfig::table4(chips)).compile_analysis(&net)?;
        let est = domino::perfmodel::estimate(&program)?;
        println!(
            "{:<18} {:>6} {:>8} {:>12} {:>10.0}",
            net.name,
            chips,
            program.total_tiles,
            est.period_cycles,
            est.images_per_s()
        );
    }

    println!("\n== pooling scheme (Fig. 4) ==");
    for (net, _) in zoo::table4_workloads() {
        let mut wd = ArchConfig::default();
        wd.pooling = PoolingScheme::WeightDuplication;
        let a = Compiler::default().compile_analysis(&net)?;
        let b = Compiler::new(wd).compile_analysis(&net)?;
        let ea = domino::perfmodel::estimate(&a)?;
        let eb = domino::perfmodel::estimate(&b)?;
        println!(
            "{:<18} block-reuse {:>6} tiles / {:>8} cyc | weight-dup {:>6} tiles / {:>8} cyc",
            net.name,
            a.total_tiles,
            ea.period_cycles,
            b.total_tiles,
            eb.period_cycles
        );
    }

    // The cost-model-driven explorer: the same search, but as a
    // first-class ranked object (pooling x placement x mesh shape x
    // chip alignment, scored analytically) — what `domino map explore`
    // prints and what `serve::api::MappingSpec` ships over the wire.
    println!("\n== cost-model-driven explorer (coordinator::explore) ==");
    use domino::coordinator::explore::{self, ExploreBounds, Objective};
    let net = zoo::resnet18_cifar();
    let cands = explore::explore(
        &net,
        &ArchConfig::default(),
        &ExploreBounds::default(),
        Objective::Latency,
    )?;
    println!(
        "{}: top candidates of {} by latency:",
        net.name,
        cands.len()
    );
    for (i, c) in cands.iter().take(6).enumerate() {
        println!(
            "  #{} {:<18} {:<13} mesh {:>2} aligned {:<3} -> {:>6} tiles, {:>8} cyc, \
             {:>6.0} img/s, {:>7.0} pJ/img, link {:>4.1}%",
            i + 1,
            c.choice.pooling.name(),
            c.choice.placement.name(),
            c.choice.mesh_cols,
            if c.choice.chip_aligned { "yes" } else { "no" },
            c.tiles,
            c.latency_cycles,
            c.images_per_s,
            c.energy_per_image_j * 1e12,
            c.worst_link_utilization * 100.0
        );
    }
    Ok(())
}
