//! ResNet skip paths on Domino: identity skips ride the RIFM→ROFM
//! shortcut (Table II `Bp.`), projected skips get their own 1x1 conv
//! tile array; the ROFM compute unit fuses add + ReLU.
//!
//!     cargo run --release --example resnet_skip

use domino::coordinator::program::StageKind;
use domino::coordinator::{ArchConfig, Compiler};
use domino::model::refcompute::{forward_all, Weights};
use domino::model::zoo;
use domino::sim::Simulator;
use domino::testutil::Rng;

fn main() -> anyhow::Result<()> {
    let net = zoo::resnet18_cifar();
    let compiler = Compiler::new(ArchConfig::table4(6));
    let weights = Weights::random(&net, compiler.weight_seed)?;
    let program = compiler.compile_with_weights(&net, &weights)?;

    println!("{}: {} tiles on {} chips", net.name, program.total_tiles, program.chips);
    println!("\nresidual junctions:");
    for (si, s) in program.stages.iter().enumerate() {
        if let StageKind::Res(r) = &s.kind {
            match &r.proj {
                Some(p) => println!(
                    "  stage {si:>2} {:<8} projected skip: 1x1/s{} conv, {} tiles (dup {}), junction dup {}",
                    s.name,
                    p.stride,
                    p.chains.iter().map(|c| c.tiles.len()).sum::<usize>() * p.dup,
                    p.dup,
                    r.dup
                ),
                None => println!(
                    "  stage {si:>2} {:<8} identity skip via RIFM->ROFM shortcut (Bp.), junction dup {}",
                    s.name, r.dup
                ),
            }
        }
    }

    // functional check: simulator == reference through all 8 blocks
    let mut rng = Rng::new(7);
    let input = rng.i8_vec(net.input_len(), 31);
    let mut sim = Simulator::new(&program);
    let got = sim.run_image(&input)?;
    let want = forward_all(
        &net,
        &weights,
        &domino::model::refcompute::Tensor::new(net.input, input),
    )?;
    assert_eq!(got.scores, want.last().unwrap().data, "sim != reference");
    println!("\ncycle simulation matches the int8 reference bit-exactly");
    println!("scores: {:?}", got.scores);
    Ok(())
}
