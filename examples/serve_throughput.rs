//! Serving load test: batched requests against the AOT-compiled
//! artifact through the inference server — closed-loop clients, latency
//! percentiles, throughput, and a per-response cross-check against the
//! Rust int8 reference.
//!
//!     make artifacts && cargo run --release --example serve_throughput

use std::sync::Arc;
use std::time::Instant;

use domino::eval::accuracy::{tiny_cnn_with_shifts, TestSet, TrainedWeights};
use domino::model::refcompute::{forward, Tensor};
use domino::runtime::{artifact, artifacts_dir};
use domino::serve::{LatencyStats, ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let ts = Arc::new(TestSet::load(&dir.join(artifact::TESTSET_BIN))?);
    let tw = TrainedWeights::load(&dir.join(artifact::WEIGHTS_BIN))?;
    let net = tiny_cnn_with_shifts(tw.shifts());
    let weights = tw.as_weights();

    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        queue_cap: 512,
    };
    println!(
        "starting server: {} workers, micro-batch {}, queue cap {}",
        cfg.workers, cfg.max_batch, cfg.queue_cap
    );
    let server = Arc::new(Server::start(cfg)?);

    // closed-loop load: 4 client threads x 128 requests over the test set
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 128;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let server = Arc::clone(&server);
        let ts = Arc::clone(&ts);
        handles.push(std::thread::spawn(move || -> anyhow::Result<(LatencyStats, Vec<(usize, Vec<i8>)>)> {
            let mut lat = LatencyStats::default();
            let mut outputs = Vec::new();
            for i in 0..PER_CLIENT {
                let idx = (c * PER_CLIENT + i) % ts.images.len();
                let t = Instant::now();
                let resp = server.infer(ts.images[idx].clone())?;
                lat.record(t.elapsed());
                outputs.push((idx, resp.logits));
            }
            Ok((lat, outputs))
        }));
    }

    let mut lat = LatencyStats::default();
    let mut all_outputs = Vec::new();
    for h in handles {
        let (l, outs) = h.join().expect("client thread")?;
        lat.merge(&l);
        all_outputs.extend(outs);
    }
    let wall = t0.elapsed();
    let total = CLIENTS * PER_CLIENT;
    println!(
        "\nserved {total} requests in {:.2} s  ->  {:.0} req/s",
        wall.as_secs_f64(),
        total as f64 / wall.as_secs_f64()
    );
    println!("latency: {}", lat.summary());
    println!("server counters: served {}, rejected {}", server.served(), server.rejected());

    // every response must equal the Rust int8 reference bit-for-bit
    let mut correct = 0usize;
    for (idx, logits) in &all_outputs {
        let want = forward(
            &net,
            &weights,
            &Tensor::new(net.input, ts.images[*idx].clone()),
        )?;
        assert_eq!(logits, &want.data, "request for image {idx} diverged");
        let pred = logits
            .iter()
            .enumerate()
            .max_by_key(|&(i, &v)| (v, std::cmp::Reverse(i)))
            .map(|(i, _)| i)
            .unwrap();
        if pred == ts.labels[*idx] as usize {
            correct += 1;
        }
    }
    println!(
        "all {} responses bit-exact vs reference; accuracy {:.4}",
        all_outputs.len(),
        correct as f64 / all_outputs.len() as f64
    );

    let counts = Arc::try_unwrap(server)
        .map_err(|_| anyhow::anyhow!("server still referenced"))?
        .shutdown()?;
    println!("per-worker served: {counts:?}");
    Ok(())
}
